//! Deterministic, seedable RNGs (SplitMix64 + PCG32).
//!
//! The vendored registry has no `rand` crate, so the simulator carries its
//! own generators. Determinism matters more than statistical perfection
//! here: every experiment in EXPERIMENTS.md is reproducible from its seed.

/// SplitMix64 — used for seeding and cheap one-off draws.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32) — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create from a seed; stream is derived from the seed via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let initstate = sm.next_u64();
        let initseq = sm.next_u64();
        let mut rng = Self { state: 0, inc: (initseq << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be > 0");
        let bound = bound as u32;
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return (r % bound) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.gen_range(i + 1);
            slice.swap(i, j);
        }
    }

    /// Sample `k` distinct elements uniformly from `0..n` (k <= n).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        // Partial Fisher–Yates over an index vector: O(n) but n is small
        // (worker counts), and it is exactly uniform.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_deterministic_and_seed_sensitive() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(1);
        let mut c = Pcg32::new(2);
        let va: Vec<u32> = (0..50).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..50).map(|_| b.next_u32()).collect();
        let vc: Vec<u32> = (0..50).map(|_| c.next_u32()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gen_f64_unit_interval_mean() {
        let mut rng = Pcg32::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.05);
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut rng = Pcg32::new(5);
        for _ in 0..200 {
            let k = 1 + rng.gen_range(8);
            let s = rng.sample_distinct(16, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {s:?}");
            assert!(s.iter().all(|&x| x < 16));
        }
    }

    #[test]
    fn sample_distinct_uniform_single() {
        // k=1 must be uniform over n.
        let mut rng = Pcg32::new(9);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[rng.sample_distinct(8, 1)[0]] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "count {c} out of tolerance");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(13);
        let mut v: Vec<usize> = (0..32).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..32).collect::<Vec<_>>());
    }
}
