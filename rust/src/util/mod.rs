//! Shared utilities: deterministic RNGs, statistics, mini-JSON.

pub mod json;
pub mod rng;
pub mod stats;
