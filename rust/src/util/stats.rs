//! Small statistics helpers for metrics and the bench harness.

/// Streaming mean/variance (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile of a sample (nearest-rank on a sorted copy); p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&p));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Simple fixed-width text histogram (for terminal reports).
pub fn text_histogram(xs: &[f64], bins: usize, width: usize) -> String {
    if xs.is_empty() || bins == 0 {
        return String::new();
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let b = (((x - lo) / span) * bins as f64) as usize;
        counts[b.min(bins - 1)] += 1;
    }
    let max = *counts.iter().max().unwrap_or(&1).max(&1);
    let mut out = String::new();
    for (i, &c) in counts.iter().enumerate() {
        let b_lo = lo + span * i as f64 / bins as f64;
        let bar = "#".repeat(c * width / max);
        out.push_str(&format!("{b_lo:>12.4} | {bar} {c}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic set is 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn histogram_counts_everything() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let h = text_histogram(&xs, 10, 20);
        assert_eq!(h.lines().count(), 10);
        let total: usize = h
            .lines()
            .map(|l| l.rsplit(' ').next().unwrap().parse::<usize>().unwrap())
            .sum();
        assert_eq!(total, 100);
    }
}
