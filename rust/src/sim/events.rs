//! Discrete-event machinery: a total-ordered f64 time plus an event queue
//! with deterministic tie-breaking (insertion sequence).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Finite f64 with a total order (panics on NaN at construction).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Time(pub f64);

impl Time {
    pub fn new(t: f64) -> Self {
        assert!(t.is_finite(), "non-finite sim time {t}");
        Time(t)
    }
}

impl Eq for Time {}

impl PartialOrd for Time {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Time {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.partial_cmp(&other.0).expect("NaN sim time")
    }
}

struct Entry<E> {
    time: Time,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with FIFO tie-break at equal times.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `ev` at absolute time `t` (must be >= now).
    pub fn push(&mut self, t: f64, ev: E) {
        debug_assert!(t >= self.now - 1e-12, "scheduling into the past: {t} < {}", self.now);
        let entry = Entry { time: Time::new(t), seq: self.seq, ev };
        self.seq += 1;
        self.heap.push(entry);
    }

    /// Pop the earliest event, advancing `now`.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| {
            self.now = e.time.0;
            (e.time.0, e.ev)
        })
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.now(), 2.0);
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_at_equal_times() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    #[should_panic]
    fn rejects_nan() {
        Time::new(f64::NAN);
    }
}
