//! AD-PSGD baseline (Lian et al. 2018), as the paper describes its
//! deployable implementation (§2.3): workers are split into an *active*
//! and a *passive* set (bipartite communication graph — required to avoid
//! the Fig. 2(a) deadlock); only actives initiate pairwise atomic
//! averaging; a worker can be in at most one synchronization at a time, so
//! overlapping syncs serialize (the conflict cost §3.1 analyses).
//!
//! Timing model: the active worker blocks on its averaging (TF
//! remote-variable round trip, calibrated `ADPSGD_SYNC_OVERHEAD`), while
//! the passive side serves syncs on its communication thread without
//! stalling its own compute — which is exactly what makes AD-PSGD
//! heterogeneity-tolerant (a slow worker only hurts when selected) yet
//! sync-dominated in wall-clock (Fig. 2b).

use crate::cluster::{calibration, ComputeTimer};
use crate::comm::CostModel;
use crate::util::rng::Pcg32;

use super::events::EventQueue;
use super::state::SimResult;
use super::SimParams;

#[derive(Debug)]
enum Ev {
    ComputeDone(usize),
    /// (active, passive, requested_at)
    SyncDone(usize, usize, f64),
}

pub fn run(params: &SimParams) -> SimResult {
    run_until(params, None)
}

pub fn run_until(params: &SimParams, time_budget: Option<f64>) -> SimResult {
    let exp = &params.exp;
    let n = exp.cluster.n_workers();
    assert!(n >= 2, "AD-PSGD needs at least one active/passive pair");
    let cost = CostModel::from_cluster(&exp.cluster);
    let mut timer = ComputeTimer::new(
        params.compute_base,
        exp.cluster.hetero.clone(),
        n,
        exp.train.seed,
    );
    let mut st = params.make_state();
    let mut rng = Pcg32::new(exp.train.seed ^ 0xADB5);
    let section = exp.algo.section_len.max(1) as u64;
    let bytes = params.model_bytes;

    // Bipartite split: even = active, odd = passive (ring-compatible).
    let passives: Vec<usize> = (0..n).filter(|w| w % 2 == 1).collect();
    let is_active = |w: usize| w % 2 == 0;

    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut iters = vec![0u64; n];
    let mut sync_free = vec![0.0f64; n];
    // scheduled duration of each worker's in-flight compute (slowdown
    // schedules make the per-iteration cost time-varying)
    let mut durs = vec![0.0f64; n];
    let mut compute_total = 0.0;
    let mut sync_total = 0.0;
    let mut conflicts = 0u64;
    let mut total_iters = 0u64;
    let max_total = exp.train.max_iters as u64 * n as u64;
    let eval_stride = (exp.train.eval_every * n) as u64;

    st.record(0.0, 0.0);
    for w in 0..n {
        durs[w] = timer.next_compute(w);
        q.push(durs[w], Ev::ComputeDone(w));
    }

    while let Some((now, ev)) = q.pop() {
        match ev {
            Ev::ComputeDone(w) => {
                st.local_step(w, iters[w]);
                iters[w] += 1;
                total_iters += 1;
                compute_total += durs[w];
                if total_iters % eval_stride == 0 {
                    st.record(now, total_iters as f64 / n as f64);
                }
                if st.done()
                    || total_iters >= max_total
                    || time_budget.is_some_and(|b| now > b)
                {
                    break;
                }
                let wants_sync = is_active(w) && iters[w] % section == 0;
                if wants_sync {
                    // pick a random passive neighbor
                    let p = if exp.algo.adpsgd_ring_only {
                        // ring neighbors of an even worker are w±1 (odd)
                        let left = (w + n - 1) % n;
                        let right = (w + 1) % n;
                        if rng.gen_range(2) == 0 {
                            left
                        } else {
                            right
                        }
                    } else {
                        passives[rng.gen_range(passives.len())]
                    };
                    // atomic pairwise averaging: serialized per worker
                    let free_at = sync_free[w].max(sync_free[p]);
                    if free_at > now {
                        conflicts += 1;
                    }
                    let start = now.max(free_at);
                    // A bandwidth throttle on either endpoint slows the
                    // whole exchange (the pair moves at the slower link).
                    let hetero = &exp.cluster.hetero;
                    let bw = hetero
                        .bandwidth_factor_at(w, iters[w])
                        .max(hetero.bandwidth_factor_at(p, iters[p]));
                    let dur = cost.pairwise_avg_throttled(
                        w,
                        p,
                        bytes,
                        calibration::ADPSGD_SYNC_OVERHEAD,
                        bw,
                    );
                    let done = start + dur;
                    sync_free[w] = done;
                    sync_free[p] = done;
                    q.push(done, Ev::SyncDone(w, p, now));
                } else {
                    // Passive workers' compute also serializes with the
                    // averaging executed on their TF graph (the remote
                    // variable is locked during the atomic update), so
                    // their next iteration starts after any in-flight
                    // sync involving them completes.
                    let start = now.max(sync_free[w]);
                    sync_total += start - now;
                    durs[w] = timer.next_compute(w);
                    q.push(start + durs[w], Ev::ComputeDone(w));
                }
            }
            Ev::SyncDone(a, p, requested_at) => {
                let mut pair = [a, p];
                pair.sort_unstable();
                st.preduce(&pair);
                // active blocked from request to completion (wait + xfer)
                sync_total += now - requested_at;
                durs[a] = timer.next_compute(a);
                q.push(now + durs[a], Ev::ComputeDone(a));
            }
        }
    }

    let final_time = q.now();
    st.record(final_time, total_iters as f64 / n as f64);
    SimResult {
        algo: "ad-psgd".to_string(),
        final_time,
        total_iters,
        per_worker_iters: iters,
        compute_time: compute_total,
        sync_time: sync_total,
        time_to_target: st.hit_time,
        avg_iters_to_target: st.hit_avg_iter,
        trace: st.trace,
        conflicts,
        gg_requests: 0,
        comm_cache_hits: 0,
        comm_cache_misses: 0,
        ..SimResult::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoKind, Experiment};
    use crate::model::MlpSpec;
    use crate::sim::rounds;

    fn params() -> SimParams {
        let mut exp = Experiment::default();
        exp.algo.kind = AlgoKind::AdPsgd;
        exp.train.max_iters = 60;
        exp.train.eval_every = 10;
        exp.train.loss_target = None;
        let mut p = SimParams::vgg16_defaults(exp);
        p.spec = MlpSpec::tiny();
        p.dataset_size = 256;
        p.batch = 32;
        p
    }

    #[test]
    fn learns_and_reports_sync_dominance() {
        let res = run(&params());
        assert!(res.total_iters > 0);
        let first = res.trace.first().unwrap().loss;
        let last = res.trace.last().unwrap().loss;
        assert!(last < first);
        // Fig. 2(b): AD-PSGD spends most of its time synchronizing.
        assert!(
            res.sync_fraction() > 0.6,
            "sync fraction {} too low",
            res.sync_fraction()
        );
    }

    #[test]
    fn tolerates_slowdown_better_than_allreduce() {
        // Fig. 1 Hetero: AD-PSGD degrades less than All-Reduce under a
        // 5x slow worker.
        let mut pa = params();
        pa.exp.algo.kind = AlgoKind::AllReduce;
        let mut pd = params();
        let ar_base = rounds::run(&pa).final_time;
        let ad_base = run(&pd).final_time;
        pa.exp.cluster.hetero.slow_worker = Some((3, 5.0));
        pd.exp.cluster.hetero.slow_worker = Some((3, 5.0));
        let ar_slow = rounds::run(&pa).final_time;
        let ad_slow = run(&pd).final_time;
        let ar_degrade = ar_slow / ar_base;
        let ad_degrade = ad_slow / ad_base;
        assert!(
            ad_degrade < ar_degrade,
            "AD-PSGD degraded {ad_degrade}x vs AR {ar_degrade}x"
        );
    }

    #[test]
    fn conflicts_occur_with_many_actives() {
        let mut p = params();
        p.exp.train.max_iters = 120;
        let res = run(&p);
        assert!(res.conflicts > 0, "expected serialization conflicts");
    }

    #[test]
    fn ring_only_mode_runs() {
        let mut p = params();
        p.exp.algo.adpsgd_ring_only = true;
        let res = run(&p);
        assert!(res.total_iters > 0);
    }

    #[test]
    fn deterministic() {
        let p = params();
        let a = run(&p);
        let b = run(&p);
        assert_eq!(a.final_time, b.final_time);
        assert_eq!(a.conflicts, b.conflicts);
    }

    #[test]
    fn deterministic_under_bandwidth_throttle() {
        // Pins the AD-PSGD hetero-bandwidth rows of BENCH_paper.json:
        // two fresh invocations must agree bit-for-bit.
        use crate::cluster::BandwidthEvent;
        let mut p = params();
        p.exp.cluster.hetero.bandwidth =
            vec![BandwidthEvent { worker: 1, factor: 16.0, start_iter: 0 }];
        let a = run(&p);
        let b = run(&p);
        assert_eq!(a.final_time, b.final_time);
        assert_eq!(a.sync_time, b.sync_time);
        assert_eq!(a.conflicts, b.conflicts);
        assert_eq!(a.trace.len(), b.trace.len());
        for (ta, tb) in a.trace.iter().zip(&b.trace) {
            assert_eq!(ta.loss.to_bits(), tb.loss.to_bits());
            assert_eq!(ta.time, tb.time);
        }
    }

    #[test]
    fn bandwidth_throttle_slows_adpsgd() {
        // Throttling every link by 1000x makes each pairwise exchange
        // several seconds longer; whatever partner sequence the shared
        // rng produces, the run cannot finish faster than the
        // full-bandwidth one.
        use crate::cluster::BandwidthEvent;
        let base = run(&params());
        let mut p = params();
        p.exp.cluster.hetero.bandwidth = (0..p.exp.cluster.n_workers())
            .map(|w| BandwidthEvent { worker: w, factor: 1000.0, start_iter: 0 })
            .collect();
        let slow = run(&p);
        assert!(
            slow.final_time > base.final_time,
            "{} vs {}",
            slow.final_time,
            base.final_time
        );
    }
}
