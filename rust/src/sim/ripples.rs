//! Ripples: event-driven simulation of P-Reduce group synchronization,
//! scheduled either by the centralized Group Generator (random or smart,
//! §4.1/§5) or by the decentralized static scheduler (§4.2).
//!
//! Semantics (faithful to Fig. 8):
//! * A worker reaching its sync point sends a request to the GG and
//!   becomes *ready*; it stays at the sync point until its *assigned*
//!   group's P-Reduce completes, but meanwhile participates in any armed
//!   group that includes it (it may have been drafted by other workers).
//! * An armed group (holds all its lock-vector bits) starts its P-Reduce
//!   as soon as every member is ready; conflicting groups wait in the
//!   GG's pending queue — that serialization is the atomicity guarantee
//!   and the cost smart GG exists to avoid.
//! * Static mode needs no locks: the schedule is conflict-free by
//!   construction; a group of schedule step `s` runs when all its members
//!   reach step `s` (rendezvous), which is also why a slow worker stalls
//!   its statically-assigned partners (§4.3).

use std::collections::{HashMap, HashSet};

use crate::cluster::{calibration, ComputeTimer};
use crate::comm::{CommCache, CostModel};
use crate::config::{AlgoKind, TopologyConfig};
use crate::gg::{GgConfig, GroupGenerator, GroupId, StaticScheduler};
use crate::util::rng::Pcg32;

use super::events::EventQueue;
use super::preduce_sync_cost;
use super::state::SimResult;
use super::SimParams;

#[derive(Debug)]
enum Ev {
    ComputeDone(usize),
    /// GG mode: group `id` with `members` finished its P-Reduce that
    /// cost `dur` virtual seconds (the overlap model needs the cost at
    /// completion time to split it into hidden vs exposed).
    PReduceDone(GroupId, Vec<usize>, f64),
    /// Static mode: the group `members` of schedule step `sidx` finished.
    StaticDone(u64, Vec<usize>),
    /// Failure repair: worker `w`'s assigned group was aborted; after the
    /// detection delay it re-requests a repaired group.
    RepairRetry(usize),
    /// Crash recovery: worker `w` checkpoint-restores and rejoins.
    Rejoin(usize),
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum WState {
    Computing,
    Ready,
    InPReduce,
}

/// Per-stage virtual time model of the staged step pipeline
/// (`[pipeline]`, mirroring `net::worker`'s loader thread). Lockstep
/// (prefetch 0) serializes the stages — every step costs
/// `load + compute` and fully exposes its load segment. With a
/// prefetching loader only each worker's first step pays the priming
/// load; afterwards a step advances at the *bottleneck* stage,
/// `max(load, compute)`, and the shorter stage's slack is metered as
/// the other stage's wait (compute stalled on the loader feeds
/// `load_wait`, an idle loader feeds `compute_wait`). `load_secs ==
/// 0.0` leaves every duration bit-for-bit identical to the
/// pre-pipeline model, staged or not.
struct StageMeter {
    load: f64,
    staged: bool,
    primed: Vec<bool>,
    load_wait: f64,
    compute_wait: f64,
}

impl StageMeter {
    fn new(pipeline: crate::step::PipelineConfig, n: usize) -> Self {
        StageMeter {
            load: pipeline.load_secs,
            staged: pipeline.is_staged(),
            primed: vec![false; n],
            load_wait: 0.0,
            compute_wait: 0.0,
        }
    }

    /// Scheduled duration of worker `w`'s next step given its raw
    /// compute cost `c`, accumulating the exposed stage waits.
    fn step_dur(&mut self, w: usize, c: f64) -> f64 {
        if !self.staged || !self.primed[w] {
            self.primed[w] = true;
            self.load_wait += self.load;
            return self.load + c;
        }
        if self.load > c {
            self.load_wait += self.load - c;
            self.load
        } else {
            self.compute_wait += c - self.load;
            c
        }
    }
}

/// Scan armed groups; start every group whose members are all ready.
/// `wire_bytes` is the codec-compressed per-member transfer size and
/// `bw` the current per-worker link throttle (1.0 = full speed); every
/// started collective adds its `2(p-1)` chunk transfers to `wire_total`.
/// `gg_service`/`gg_shards` feed the coordinator-contention model: each
/// start's GG round trip waits behind the still-Ready workers racing the
/// GG, spread across the shards (identity at `gg_service == 0`).
#[allow(clippy::too_many_arguments)]
fn start_runnable(
    armed: &mut HashMap<GroupId, Vec<usize>>,
    wstate: &mut [WState],
    q: &mut EventQueue<Ev>,
    now: f64,
    cost: &CostModel,
    topo: &TopologyConfig,
    cache: &mut CommCache,
    wire_bytes: usize,
    bw: &[f64],
    wire_total: &mut u64,
    gg_service: f64,
    gg_shards: usize,
) {
    let mut runnable: Vec<GroupId> = armed
        .iter()
        .filter(|(_, m)| m.iter().all(|&x| wstate[x] == WState::Ready))
        .map(|(&id, _)| id)
        .collect();
    // HashMap iteration order is randomized per process; start groups in
    // creation order so simulations are bit-for-bit reproducible per seed.
    runnable.sort_unstable();
    for gid in runnable {
        let members = armed.remove(&gid).unwrap();
        for &m in &members {
            wstate[m] = WState::InPReduce;
        }
        // workers sitting at their sync point right now are in the GG's
        // request/notify queues alongside this group's start
        let outstanding = wstate.iter().filter(|&&s| s == WState::Ready).count();
        let dur = cost.gg_rtt_contended(outstanding, gg_service, gg_shards)
            + cache.acquire(&members)
            + preduce_sync_cost(cost, topo, &members, wire_bytes, bw)
            + calibration::PREDUCE_OVERHEAD;
        *wire_total += 2 * members.len().saturating_sub(1) as u64 * wire_bytes as u64;
        q.push(now + dur, Ev::PReduceDone(gid, members, dur));
    }
}

pub fn run(params: &SimParams) -> SimResult {
    run_until(params, None)
}

/// Run with an explicit GG configuration (the ablation harness toggles
/// individual §5 mechanisms; see `bench::ablation`).
pub fn run_with_gg(params: &SimParams, gg_cfg: GgConfig) -> SimResult {
    run_inner(params, None, Some(gg_cfg))
}

pub fn run_until(params: &SimParams, time_budget: Option<f64>) -> SimResult {
    run_inner(params, time_budget, None)
}

fn run_inner(
    params: &SimParams,
    time_budget: Option<f64>,
    gg_override: Option<GgConfig>,
) -> SimResult {
    let exp = &params.exp;
    let n = exp.cluster.n_workers();
    let kind = exp.algo.kind;
    let cost = CostModel::from_cluster(&exp.cluster);
    let mut timer = ComputeTimer::new(
        params.compute_base,
        exp.cluster.hetero.clone(),
        n,
        exp.train.seed,
    );
    let mut st = params.make_state();
    let mut rng = Pcg32::new(exp.train.seed ^ 0x8199_1e5);
    let mut cache = CommCache::new(64, calibration::COMM_CREATE_COST);
    // bytes-on-wire model: the configured codec compresses every chunk,
    // so the cost model charges (and meters) compressed bytes
    let wire = exp.wire;
    let bytes = wire.wire_bytes(params.model_bytes);
    let mut wire_total = 0u64;
    let section = exp.algo.section_len.max(1) as u64;

    let mut gg = match (gg_override, kind) {
        (Some(cfg), _) => Some(GroupGenerator::new(cfg)),
        (None, AlgoKind::RipplesRandom) => Some(GroupGenerator::new(GgConfig::random(
            n,
            exp.cluster.workers_per_node,
            exp.algo.group_size,
        ))),
        (None, AlgoKind::RipplesSmart) => Some(GroupGenerator::new(GgConfig::smart(
            n,
            exp.cluster.workers_per_node,
            exp.algo.group_size,
            exp.algo.c_thres,
        ))),
        (None, AlgoKind::RipplesStatic) => None,
        (None, other) => unreachable!("ripples engine got {other:?}"),
    };
    let sched = StaticScheduler::new(exp.cluster.n_nodes, exp.cluster.workers_per_node);

    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut wstate = vec![WState::Computing; n];
    let mut ready_since = vec![0.0f64; n];
    // Scheduled duration of each worker's in-flight compute: the virtual
    // "timestamped SGD step" the GG's speed table observes, mirroring
    // the SpeedReport piggyback of the distributed runtime.
    let mut durs = vec![0.0f64; n];
    let mut onset_request: Option<u64> = None;
    let hetero = exp.cluster.hetero.clone();
    // per-link bandwidth throttle (divisor; 1.0 = full speed),
    // re-resolved as each worker's local iteration advances
    let mut bw_div: Vec<f64> =
        (0..n).map(|w| hetero.bandwidth_factor_at(w, 0)).collect();
    let mut assigned: Vec<Option<GroupId>> = vec![None; n];
    // armed but not yet started: id -> members
    let mut armed: HashMap<GroupId, Vec<usize>> = HashMap::new();
    // static-mode rendezvous: (sidx, lead member) -> arrivals so far
    let mut rendezvous: HashMap<(u64, usize), usize> = HashMap::new();

    let mut iters = vec![0u64; n];
    let mut compute_total = 0.0;
    let mut sync_total = 0.0;
    // §Perf overlap model: with `[overlap]` enabled, part of each
    // member's sync wait is *hidden* behind up to `max_staleness` stale
    // SGD steps while the pipelined collective is in flight; only the
    // final shard's transfer (dur/K) is always exposed — the training
    // thread cannot apply a shard before it lands. Serial (staleness 0)
    // leaves the original arithmetic untouched, bit for bit.
    let overlap = exp.overlap;
    let mut hidden_total = 0.0;
    // §Perf staged pipeline: every step duration flows through the
    // stage meter, which models the loader/compute handoff and meters
    // the per-stage exposed waits (identity at the default config).
    let mut stage = StageMeter::new(exp.pipeline, n);
    let mut total_iters = 0u64;
    let max_total = exp.train.max_iters as u64 * n as u64;
    let eval_stride = (exp.train.eval_every * n) as u64;
    // ---- crash model (`CrashEvent` ground truth, `[faults]` policy):
    // a worker dies mid-iteration; with repair on, the GG declares it
    // dead after `detect_secs` — groups naming it abort, stranded
    // partners re-request; with repair off the locks are never released
    // (the AD-PSGD deadlock class) and the run ends in a stall.
    let faults = exp.faults;
    let mut dead_now = vec![false; n]; // currently crashed
    let mut crash_fired = vec![false; n]; // sticky: each event fires once
    let mut deaths = 0u64;
    let mut rejoins = 0u64;
    let mut deadlocked = false;

    st.record(0.0, 0.0);
    for w in 0..n {
        durs[w] = stage.step_dur(w, timer.next_compute(w));
        q.push(durs[w], Ev::ComputeDone(w));
    }

    while let Some((now, ev)) = q.pop() {
        match ev {
            Ev::ComputeDone(w) => {
                // crash hook: the worker dies mid-iteration `at_iter` —
                // this step never completes, no more events for `w`
                if !crash_fired[w]
                    && hetero.crash_of(w).is_some_and(|ev| ev.at_iter == iters[w])
                {
                    let cev = *hetero.crash_of(w).expect("checked above");
                    crash_fired[w] = true;
                    dead_now[w] = true;
                    deaths += 1;
                    if faults.repair {
                        if let Some(gg) = gg.as_mut() {
                            let purge = gg.declare_dead(w);
                            let aborted_ids: HashSet<GroupId> =
                                purge.aborted.iter().map(|g| g.id).collect();
                            armed.retain(|id, _| !aborted_ids.contains(id));
                            // partners stranded at their sync point
                            // re-request once the failure is detected
                            for g in &purge.aborted {
                                for &m in &g.members {
                                    if m != w
                                        && !dead_now[m]
                                        && wstate[m] == WState::Ready
                                        && assigned[m]
                                            .is_some_and(|a| aborted_ids.contains(&a))
                                    {
                                        assigned[m] = None;
                                        q.push(
                                            now + faults.detect_secs,
                                            Ev::RepairRetry(m),
                                        );
                                    }
                                }
                            }
                            for g in purge.newly_armed {
                                armed.insert(g.id, g.members);
                            }
                            start_runnable(
                                &mut armed, &mut wstate, &mut q, now, &cost,
                                &exp.topology, &mut cache, bytes, &bw_div,
                                &mut wire_total, params.gg_service, params.gg_shards,
                            );
                        }
                    }
                    if let Some(r) = cev.rejoin_after_secs {
                        q.push(now + r, Ev::Rejoin(w));
                    }
                    continue;
                }
                st.local_step(w, iters[w]);
                let it = iters[w];
                iters[w] += 1;
                bw_div[w] = hetero.bandwidth_factor_at(w, iters[w]);
                total_iters += 1;
                compute_total += durs[w];
                if let Some(gg) = gg.as_mut() {
                    // measured telemetry: the step the worker just timed
                    gg.observe_speed(w, durs[w]);
                    if onset_request.is_none() && hetero.schedule_active(w, it) {
                        onset_request = Some(gg.stats.requests);
                    }
                }
                if total_iters % eval_stride == 0 {
                    st.record(now, total_iters as f64 / n as f64);
                }
                if st.done()
                    || total_iters >= max_total
                    || time_budget.is_some_and(|b| now > b)
                {
                    break;
                }
                if (it + 1) % section != 0 {
                    durs[w] = stage.step_dur(w, timer.next_compute(w));
                    q.push(now + durs[w], Ev::ComputeDone(w));
                    continue;
                }
                wstate[w] = WState::Ready;
                ready_since[w] = now;
                if let Some(gg) = gg.as_mut() {
                    let (gid, newly) = gg.request(w, &mut rng);
                    match gid {
                        Some(gid) => assigned[w] = Some(gid),
                        None => {
                            // no sync possible (cannot happen in the sim's
                            // never-retiring workload, but stay graceful)
                            wstate[w] = WState::Computing;
                            durs[w] = stage.step_dur(w, timer.next_compute(w));
                            q.push(now + durs[w], Ev::ComputeDone(w));
                        }
                    }
                    for g in newly {
                        armed.insert(g.id, g.members);
                    }
                    start_runnable(
                        &mut armed, &mut wstate, &mut q, now, &cost, &exp.topology,
                        &mut cache, bytes, &bw_div, &mut wire_total,
                        params.gg_service, params.gg_shards,
                    );
                } else {
                    // static scheduling: one schedule step per section
                    let sidx = it / section;
                    match sched.group_of(w, sidx) {
                        None => {
                            wstate[w] = WState::Computing;
                            durs[w] = stage.step_dur(w, timer.next_compute(w));
                            q.push(now + durs[w], Ev::ComputeDone(w));
                        }
                        Some(members) => {
                            let key = (sidx, members[0]);
                            let arrived = rendezvous.entry(key).or_insert(0);
                            *arrived += 1;
                            if *arrived == members.len() {
                                rendezvous.remove(&key);
                                for &m in &members {
                                    wstate[m] = WState::InPReduce;
                                }
                                let dur = cache.acquire(&members)
                                    + preduce_sync_cost(
                                        &cost, &exp.topology, &members, bytes, &bw_div,
                                    )
                                    + calibration::PREDUCE_OVERHEAD;
                                wire_total += 2
                                    * members.len().saturating_sub(1) as u64
                                    * bytes as u64;
                                q.push(now + dur, Ev::StaticDone(sidx, members));
                            }
                        }
                    }
                }
            }
            Ev::PReduceDone(gid, members, dur) => {
                st.preduce_coded(&members, wire);
                {
                    let gg = gg.as_mut().expect("PReduceDone without GG");
                    for g in gg.complete(gid) {
                        armed.insert(g.id, g.members);
                    }
                }
                for &m in &members {
                    if assigned[m] == Some(gid) {
                        // this was m's own sync step: resume compute
                        assigned[m] = None;
                        wstate[m] = WState::Computing;
                        durs[m] = stage.step_dur(m, timer.next_compute(m));
                        if overlap.max_staleness > 0 {
                            // Hidden = what stale compute can cover: up
                            // to `S` steps' worth, never the final
                            // shard's fill (dur/K), never more than the
                            // wait. Schedule credit is capped at ONE
                            // step — the sim does not synthesize extra
                            // iteration events for deeper staleness, it
                            // only re-classifies the wait as hidden.
                            let wait = now - ready_since[m];
                            let cap = (overlap.max_staleness as f64) * durs[m];
                            let overlappable = wait - dur / overlap.shards.max(1) as f64;
                            let hidden = cap.min(overlappable).max(0.0);
                            sync_total += wait - hidden;
                            hidden_total += hidden;
                            // that compute already ran inside the wait
                            let credit = hidden.min(durs[m]);
                            q.push(now + durs[m] - credit, Ev::ComputeDone(m));
                        } else {
                            sync_total += now - ready_since[m];
                            q.push(now + durs[m], Ev::ComputeDone(m));
                        }
                    } else {
                        // drafted into someone else's group: stay ready
                        wstate[m] = WState::Ready;
                        // repair orphan: m's own assigned group was
                        // aborted (a group cannot complete without m
                        // participating, so "gone" here always means
                        // aborted) — re-request right away
                        let orphaned = match assigned[m] {
                            None => true,
                            Some(a) => {
                                gg.as_ref().is_some_and(|g| g.group(a).is_none())
                            }
                        };
                        if orphaned && !dead_now[m] {
                            assigned[m] = None;
                            q.push(now, Ev::RepairRetry(m));
                        }
                    }
                }
                start_runnable(
                    &mut armed, &mut wstate, &mut q, now, &cost, &exp.topology,
                    &mut cache, bytes, &bw_div, &mut wire_total,
                    params.gg_service, params.gg_shards,
                );
            }
            Ev::StaticDone(_sidx, members) => {
                st.preduce_coded(&members, wire);
                for &m in &members {
                    wstate[m] = WState::Computing;
                    sync_total += now - ready_since[m];
                    durs[m] = stage.step_dur(m, timer.next_compute(m));
                    q.push(now + durs[m], Ev::ComputeDone(m));
                }
            }
            Ev::RepairRetry(m) => {
                // stale once the worker moved on (resumed compute, joined
                // a collective, crashed, or a prior retry succeeded)
                if !dead_now[m] && wstate[m] == WState::Ready && assigned[m].is_none() {
                    let gg = gg.as_mut().expect("repair retry without GG");
                    let (gid, newly) = gg.request(m, &mut rng);
                    match gid {
                        Some(gid) => assigned[m] = Some(gid),
                        None => {
                            // nobody left to pair with: skip this sync
                            wstate[m] = WState::Computing;
                            durs[m] = stage.step_dur(m, timer.next_compute(m));
                            q.push(now + durs[m], Ev::ComputeDone(m));
                        }
                    }
                    for g in newly {
                        armed.insert(g.id, g.members);
                    }
                    start_runnable(
                        &mut armed, &mut wstate, &mut q, now, &cost, &exp.topology,
                        &mut cache, bytes, &bw_div, &mut wire_total,
                        params.gg_service, params.gg_shards,
                    );
                }
            }
            Ev::Rejoin(w) => {
                if dead_now[w] {
                    dead_now[w] = false;
                    rejoins += 1;
                    if faults.repair {
                        if let Some(gg) = gg.as_mut() {
                            // re-registers the declared-dead rank; no
                            // groups to purge (death already aborted them)
                            let _ = gg.rejoin(w);
                        }
                    }
                    // checkpoint-restore: seed from the freshest live
                    // replica (net::ckpt's "freshest in the shared dir")
                    if let Some(best) = (0..n)
                        .filter(|&x| x != w && !dead_now[x])
                        .max_by_key(|&x| iters[x])
                    {
                        st.models[w] = st.models[best].clone();
                    }
                    wstate[w] = WState::Computing;
                    assigned[w] = None;
                    // the restored process starts its loader cold: the
                    // first post-rejoin step pays the priming load again
                    stage.primed[w] = false;
                    durs[w] = stage.step_dur(w, timer.next_compute(w));
                    q.push(now + durs[w], Ev::ComputeDone(w));
                }
            }
        }
        if q.is_empty() && total_iters < max_total && !st.done() {
            if dead_now.iter().any(|&d| d) {
                // every live worker is blocked on a group naming a
                // crashed rank whose locks were never released: the
                // no-repair failure mode. This IS the measurement —
                // report the partial run instead of panicking.
                deadlocked = true;
                break;
            }
            panic!(
                "simulation stalled at t={}: states {:?}, armed {:?}, pending {}",
                q.now(),
                wstate,
                armed.keys().collect::<Vec<_>>(),
                gg.as_ref().map(|g| g.pending_len()).unwrap_or(0)
            );
        }
    }

    let final_time = q.now();
    st.record(final_time, total_iters as f64 / n as f64);
    let (conflicts, requests) = gg
        .as_ref()
        .map(|g| (g.stats.conflicts, g.stats.requests))
        .unwrap_or((0, 0));
    let (measured_speeds, drafts, last_drafted_request) = gg
        .as_ref()
        .map(|g| {
            (g.speed_table().snapshot(), g.drafts().to_vec(), g.last_drafted().to_vec())
        })
        .unwrap_or_default();
    SimResult {
        algo: kind.name().to_string(),
        final_time,
        total_iters,
        per_worker_iters: iters,
        compute_time: compute_total,
        sync_time: sync_total,
        hidden_sync_time: hidden_total,
        load_wait_time: stage.load_wait,
        compute_wait_time: stage.compute_wait,
        reconcile_wait_time: sync_total,
        time_to_target: st.hit_time,
        avg_iters_to_target: st.hit_avg_iter,
        trace: st.trace,
        conflicts,
        gg_requests: requests,
        comm_cache_hits: cache.hits,
        comm_cache_misses: cache.misses,
        measured_speeds,
        drafts,
        last_drafted_request,
        onset_request,
        deaths,
        groups_aborted: gg.as_ref().map(|g| g.stats.groups_aborted).unwrap_or(0),
        rejoins,
        deadlocked,
        bytes_on_wire: wire_total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Experiment, SyncShape};
    use crate::model::MlpSpec;
    use crate::sim::{adpsgd, rounds};

    fn params(kind: AlgoKind) -> SimParams {
        let mut exp = Experiment::default();
        exp.algo.kind = kind;
        exp.train.max_iters = 60;
        exp.train.eval_every = 10;
        exp.train.loss_target = None;
        let mut p = SimParams::vgg16_defaults(exp);
        p.spec = MlpSpec::tiny();
        p.dataset_size = 256;
        p.batch = 32;
        p
    }

    #[test]
    fn topology_shapes_trade_time_not_loss() {
        // 2 machines of 4 behind a constrained 1.5 GB/s uplink, VGG-size
        // transfers. The static schedule fixes every group independent of
        // virtual time, so all four placement shapes run bit-identical
        // arithmetic — the shape may only move the clock (the "equal
        // loss" leg of the fig-topo acceptance; the 2x sync claim lives
        // on the all-reduce anchor in `rounds`, whose global group
        // actually puts several members per machine into one crossing
        // collective). The static schedule's *crossing* groups are all
        // one-member-per-machine (heads, opposite-node rank-1 pairs), so
        // blind/ordered/hier coincide there; hier still differs on the
        // intra-node phases (full-size member<->leader transfers vs a
        // chunked ring), which is exactly why the GG's planner keeps
        // single-machine groups flat.
        let mk = |shape: SyncShape| {
            let mut exp = Experiment::default();
            exp.algo.kind = AlgoKind::RipplesStatic;
            exp.cluster.n_nodes = 2;
            exp.cluster.workers_per_node = 4;
            exp.cluster.link.inter_bw = 1.5e9;
            exp.train.max_iters = 40;
            exp.train.eval_every = 10;
            exp.topology.shape = shape;
            let mut p = SimParams::vgg16_defaults(exp);
            p.spec = MlpSpec::tiny();
            p.dataset_size = 256;
            p.batch = 32;
            p.model_bytes = 38_720_000;
            run(&p)
        };
        let flat = mk(SyncShape::Flat);
        let blind = mk(SyncShape::FlatBlind);
        let ordered = mk(SyncShape::FlatOrdered);
        let hier = mk(SyncShape::Hier);
        let loss = flat.trace.last().unwrap().loss;
        for (name, r) in [("blind", &blind), ("ordered", &ordered), ("hier", &hier)] {
            assert_eq!(r.total_iters, flat.total_iters, "{name}");
            assert_eq!(
                r.trace.last().unwrap().loss.to_bits(),
                loss.to_bits(),
                "{name}: placement shape changed the arithmetic"
            );
        }
        // shape reaches the cost model: forcing every group two-level
        // taxes the intra-node phases, so hier costs *more* here
        assert!(
            hier.sync_time > flat.sync_time,
            "hier must differ from flat on intra-node groups: {} vs {}",
            hier.sync_time,
            flat.sync_time
        );
        // node-major flat order is the degenerate no-op on this schedule
        assert!((ordered.sync_time - flat.sync_time).abs() < 1e-6 * flat.sync_time.max(1.0));
    }

    #[test]
    fn gg_contention_costs_time_and_sharding_recovers_it() {
        // service = 0 must be bit-identical to the pre-contention model,
        // regardless of the shard count (the shards knob is ignored).
        let base = run(&params(AlgoKind::RipplesRandom));
        let mut zero = params(AlgoKind::RipplesRandom);
        zero.gg_shards = 16;
        let z = run(&zero);
        assert_eq!(z.final_time.to_bits(), base.final_time.to_bits());
        assert_eq!(z.total_iters, base.total_iters);

        // A busy single-lock coordinator slows the run; 16 shards divide
        // the queue and claw most of the loss back.
        let mut locked = params(AlgoKind::RipplesRandom);
        locked.gg_service = 5e-3;
        locked.gg_shards = 1;
        let slow = run(&locked);
        let mut sharded = locked.clone();
        sharded.gg_shards = 16;
        let fast = run(&sharded);
        assert!(
            slow.final_time > base.final_time,
            "contention free: {} vs {}",
            slow.final_time,
            base.final_time
        );
        assert!(
            fast.final_time < slow.final_time,
            "sharding did not help: {} vs {}",
            fast.final_time,
            slow.final_time
        );
        assert!(fast.final_time >= base.final_time);
    }

    #[test]
    fn all_three_ripples_variants_complete() {
        for kind in [
            AlgoKind::RipplesStatic,
            AlgoKind::RipplesRandom,
            AlgoKind::RipplesSmart,
        ] {
            let res = run(&params(kind));
            assert_eq!(res.total_iters, 60 * 16, "{kind:?}");
            assert!(res.final_time > 0.0);
        }
    }

    #[test]
    fn smart_gg_conflicts_fewer_than_random() {
        // §5.1's whole point: GB + GD avoid the serialization conflicts
        // plain random group generation produces constantly.
        let random = run(&params(AlgoKind::RipplesRandom));
        let smart = run(&params(AlgoKind::RipplesSmart));
        assert!(
            smart.conflicts < random.conflicts,
            "smart {} vs random {}",
            smart.conflicts,
            random.conflicts
        );
        assert!(smart.final_time < random.final_time);
    }

    #[test]
    fn static_has_zero_conflicts() {
        let res = run(&params(AlgoKind::RipplesStatic));
        assert_eq!(res.conflicts, 0);
    }

    #[test]
    fn ripples_static_beats_allreduce_homogeneous() {
        // Fig. 17's headline: Ripples static > All-Reduce per-iteration
        // in homogeneous clusters (smaller groups, no global barrier).
        let mut pa = params(AlgoKind::AllReduce);
        let rs = run(&params(AlgoKind::RipplesStatic));
        let ar = rounds::run(&pa);
        assert!(
            rs.per_iter_time() < ar.per_iter_time(),
            "ripples {} vs AR {}",
            rs.per_iter_time(),
            ar.per_iter_time()
        );
        pa.exp.algo.kind = AlgoKind::ParameterServer;
        let ps = rounds::run(&pa);
        assert!(rs.per_iter_time() < ps.per_iter_time());
    }

    #[test]
    fn ripples_beats_adpsgd_throughput() {
        // P-Reduce (one collective) vs pairwise atomic averaging with the
        // TF remote-variable overhead: Ripples should iterate much faster.
        let rs = run(&params(AlgoKind::RipplesSmart));
        let ad = adpsgd::run(&params(AlgoKind::AdPsgd));
        assert!(
            rs.per_iter_time() < ad.per_iter_time(),
            "ripples {} vs adpsgd {}",
            rs.per_iter_time(),
            ad.per_iter_time()
        );
    }

    #[test]
    fn smart_tolerates_slowdown_better_than_static() {
        // Fig. 19: with a 5x slow worker, static's fixed schedule stalls
        // its partners while smart GG routes around the laggard.
        let mut ps = params(AlgoKind::RipplesStatic);
        let mut pm = params(AlgoKind::RipplesSmart);
        let static_base = run(&ps).final_time;
        let smart_base = run(&pm).final_time;
        ps.exp.cluster.hetero.slow_worker = Some((5, 5.0));
        pm.exp.cluster.hetero.slow_worker = Some((5, 5.0));
        let static_slow = run(&ps).final_time;
        let smart_slow = run(&pm).final_time;
        let static_degrade = static_slow / static_base;
        let smart_degrade = smart_slow / smart_base;
        assert!(
            smart_degrade < static_degrade,
            "smart degraded {smart_degrade}x vs static {static_degrade}x"
        );
    }

    #[test]
    fn dynamic_straggler_measured_and_filtered() {
        use crate::cluster::SlowdownEvent;
        let mut p = params(AlgoKind::RipplesSmart);
        p.exp.train.max_iters = 120;
        p.exp.cluster.hetero.schedule =
            vec![SlowdownEvent { worker: 7, factor: 6.0, start_iter: 40 }];
        let res = run(&p);
        // the schedule fired and the GG observed it
        let onset = res.onset_request.expect("schedule never activated");
        assert!(res.gg_requests > onset);
        // measured relative speed converged to the true 6x (within 30%)
        let rel = crate::metrics::relative_speeds(&res.measured_speeds);
        assert!(
            (rel[7] - 6.0).abs() < 0.3 * 6.0,
            "measured {} vs true 6.0 (speeds {:?})",
            rel[7],
            res.measured_speeds
        );
        for w in 0..7 {
            assert!(rel[w] < 1.5, "fast worker {w} mis-measured at {}", rel[w]);
        }
        // the filter reacted: the straggler was drafted before the onset
        // but stops being drafted shortly after it
        assert!(res.drafts[7] > 0, "straggler never drafted pre-onset");
        assert!(
            res.gg_requests - res.last_drafted_request[7] > 200,
            "straggler still drafted near the end: last at {} of {} (onset {})",
            res.last_drafted_request[7],
            res.gg_requests,
            onset
        );
    }

    #[test]
    fn recovered_straggler_readmitted_only_with_measured_filter() {
        use crate::cluster::SlowdownEvent;
        // slow from iter 20, recovered from iter 32 (early enough that
        // the 6x-slowed worker reaches it inside the total-iteration
        // budget): the counter filter alone can never re-admit (the
        // progress deficit is frozen), the measured filter re-admits
        // within ~1/alpha steps
        let schedule = vec![
            SlowdownEvent { worker: 7, factor: 6.0, start_iter: 20 },
            SlowdownEvent { worker: 7, factor: 1.0, start_iter: 32 },
        ];
        let mut measured = params(AlgoKind::RipplesSmart);
        measured.exp.train.max_iters = 200;
        measured.exp.cluster.hetero.schedule = schedule.clone();
        let mut counter_only_cfg = GgConfig::smart(16, 4, 3, 8);
        counter_only_cfg.s_thres = None;
        let with_measured = run(&measured);
        let counter_only = super::run_with_gg(&measured, counter_only_cfg);
        // measured filter: drafted again near the end of the run
        assert!(
            with_measured.gg_requests - with_measured.last_drafted_request[7] < 400,
            "recovered worker not re-admitted: last drafted {} of {}",
            with_measured.last_drafted_request[7],
            with_measured.gg_requests
        );
        // counter-only filter: exclusion persists long after recovery
        assert!(
            counter_only.gg_requests - counter_only.last_drafted_request[7]
                > with_measured.gg_requests - with_measured.last_drafted_request[7],
            "counter filter re-admitted as fast as the measured one: {} vs {}",
            counter_only.last_drafted_request[7],
            with_measured.last_drafted_request[7]
        );
    }

    #[test]
    fn overlap_hides_sync_deterministically() {
        let mut serial = params(AlgoKind::RipplesSmart);
        serial.exp.train.max_iters = 80;
        let mut over = serial.clone();
        over.exp.overlap =
            crate::collectives::OverlapConfig { shards: 4, max_staleness: 4 };
        let rs = run(&serial);
        let ro = run(&over);
        // serial keeps the legacy accounting: nothing hidden
        assert_eq!(rs.hidden_sync_time, 0.0);
        assert_eq!(rs.hidden_sync_share(), 0.0);
        // overlap hides real sync cost and never slows the run down
        assert!(ro.hidden_sync_time > 0.0, "nothing hidden: {ro:?}");
        assert!(
            ro.sync_fraction() < rs.sync_fraction(),
            "exposed sync did not drop: {} vs {}",
            ro.sync_fraction(),
            rs.sync_fraction()
        );
        assert!(
            ro.final_time <= rs.final_time * 1.05,
            "overlap slowed the run: {} vs {}",
            ro.final_time,
            rs.final_time
        );
        assert_eq!(rs.total_iters, ro.total_iters, "iteration budget changed");
        // the overlap path is as deterministic as the serial one
        let ro2 = run(&over);
        assert_eq!(ro.final_time.to_bits(), ro2.final_time.to_bits());
        assert_eq!(ro.sync_time.to_bits(), ro2.sync_time.to_bits());
        assert_eq!(ro.hidden_sync_time.to_bits(), ro2.hidden_sync_time.to_bits());
    }

    #[test]
    fn staged_pipeline_makespan_is_bottleneck_not_sum() {
        // lockstep pays load + compute every step and exposes the whole
        // load segment; a primed staged loader pays only the bottleneck
        // max(load, compute), so with load at 0.4x the compute base the
        // staged run must be strictly faster and expose strictly less
        // load wait, idling the loader (compute_wait > 0) instead.
        let base = run(&params(AlgoKind::RipplesSmart));
        let mut lock = params(AlgoKind::RipplesSmart);
        lock.exp.pipeline.load_secs = 0.4 * lock.compute_base;
        let mut staged = lock.clone();
        staged.exp.pipeline.prefetch = 4;
        let rl = run(&lock);
        let rs = run(&staged);
        assert_eq!(rl.total_iters, rs.total_iters);
        assert!(rl.final_time > base.final_time);
        assert!(rl.load_wait_time > 0.0);
        assert_eq!(rl.compute_wait_time, 0.0);
        assert!(
            rs.load_wait_time < rl.load_wait_time,
            "prefetch did not cut exposed load wait: {} vs {}",
            rs.load_wait_time,
            rl.load_wait_time
        );
        assert!(
            rs.final_time < rl.final_time,
            "staged makespan not below lockstep: {} vs {}",
            rs.final_time,
            rl.final_time
        );
        assert!(rs.compute_wait_time > 0.0, "loader never idled: {rs:?}");
        // reconcile wait is the stage-named view of the sync meter
        assert_eq!(rs.reconcile_wait_time.to_bits(), rs.sync_time.to_bits());
        assert_eq!(rl.reconcile_wait_time.to_bits(), rl.sync_time.to_bits());
    }

    #[test]
    fn staged_time_model_deterministic_and_identity_at_zero_load() {
        // prefetch with zero load cost must not move a single event:
        // the bottleneck max(0, c) is bitwise c, so the whole schedule
        // (and the loss trace riding on it) is unchanged.
        let base = run(&params(AlgoKind::RipplesSmart));
        let mut zero = params(AlgoKind::RipplesSmart);
        zero.exp.pipeline.prefetch = 4;
        let z = run(&zero);
        assert_eq!(z.final_time.to_bits(), base.final_time.to_bits());
        assert_eq!(z.total_iters, base.total_iters);
        assert_eq!(base.load_wait_time, 0.0);
        assert_eq!(base.compute_wait_time, 0.0);
        for (x, y) in base.trace.iter().zip(z.trace.iter()) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        }

        // staged runs with per-stage durations enabled stay bit-for-bit
        // reproducible, crashes + overlap included (satellite of the
        // determinism suite: the stage meter adds no RNG draws)
        use crate::cluster::CrashEvent;
        let mut p = params(AlgoKind::RipplesSmart);
        p.exp.train.max_iters = 100;
        p.exp.pipeline.prefetch = 4;
        p.exp.pipeline.load_secs = 0.5 * p.compute_base;
        p.exp.overlap =
            crate::collectives::OverlapConfig { shards: 4, max_staleness: 4 };
        p.exp.cluster.hetero.crashes =
            vec![CrashEvent { worker: 3, at_iter: 15, rejoin_after_secs: Some(2.0) }];
        let a = run(&p);
        let b = run(&p);
        assert_eq!(a.final_time.to_bits(), b.final_time.to_bits());
        assert_eq!(a.load_wait_time.to_bits(), b.load_wait_time.to_bits());
        assert_eq!(a.compute_wait_time.to_bits(), b.compute_wait_time.to_bits());
        assert_eq!(
            a.reconcile_wait_time.to_bits(),
            b.reconcile_wait_time.to_bits()
        );
        assert_eq!(a.per_worker_iters, b.per_worker_iters);
        assert_eq!(a.rejoins, b.rejoins);
        for (x, y) in a.trace.iter().zip(b.trace.iter()) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        }
    }

    #[test]
    fn compressed_wire_cuts_bytes_and_constrained_sync_time() {
        use crate::cluster::BandwidthEvent;
        use crate::collectives::WireCodec;
        // every link throttled 512x: the ring, not the straggler, is the
        // bottleneck — the scenario the wire codecs exist for
        let constrained = |wire: WireCodec| {
            let mut p = params(AlgoKind::RipplesSmart);
            p.exp.wire = wire;
            p.exp.cluster.hetero.bandwidth = (0..16)
                .map(|w| BandwidthEvent { worker: w, factor: 512.0, start_iter: 0 })
                .collect();
            p
        };
        let rf = run(&constrained(WireCodec::Fp32));
        let rq = run(&constrained(WireCodec::Q8));
        // same schedule length, ~4x fewer bytes, >=2x less exposed sync
        assert_eq!(rf.total_iters, rq.total_iters);
        assert!(rq.bytes_on_wire > 0);
        assert!(
            rq.bytes_on_wire * 3 < rf.bytes_on_wire,
            "q8 bytes {} vs fp32 {}",
            rq.bytes_on_wire,
            rf.bytes_on_wire
        );
        assert!(
            rq.sync_time <= 0.5 * rf.sync_time,
            "q8 sync {} vs fp32 {} not >=2x better",
            rq.sync_time,
            rf.sync_time
        );
        // the throttle itself is what made fp32 expensive
        let uniform = run(&params(AlgoKind::RipplesSmart));
        assert!(
            rf.sync_time > 2.0 * uniform.sync_time,
            "constrained {} vs uniform {}",
            rf.sync_time,
            uniform.sync_time
        );
        // codec + bandwidth model stay bit-for-bit deterministic
        let rq2 = run(&constrained(WireCodec::Q8));
        assert_eq!(rq.final_time.to_bits(), rq2.final_time.to_bits());
        assert_eq!(rq.bytes_on_wire, rq2.bytes_on_wire);
        for (x, y) in rq.trace.iter().zip(rq2.trace.iter()) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        }
    }

    #[test]
    fn crash_with_repair_outlives_crash_without() {
        use crate::cluster::CrashEvent;
        let mut base = params(AlgoKind::RipplesSmart);
        base.exp.train.max_iters = 80;
        let crash_free = run(&base);
        let budget = crash_free.final_time; // equal-virtual-time comparison

        let mut crashed = base.clone();
        crashed.exp.cluster.hetero.crashes =
            vec![CrashEvent { worker: 7, at_iter: 30, rejoin_after_secs: None }];
        let repaired = super::run_until(&crashed, Some(budget));
        assert_eq!(repaired.deaths, 1);
        assert!(!repaired.deadlocked, "repair must keep the cluster alive");
        assert_eq!(
            repaired.per_worker_iters[7],
            30,
            "the dead worker stops at its crash iteration"
        );
        // every survivor keeps iterating after the repair: nobody frozen
        let min_live = (0..16)
            .filter(|&w| w != 7)
            .map(|w| repaired.per_worker_iters[w])
            .min()
            .unwrap();
        assert!(
            min_live > 40,
            "a survivor froze despite repair: {:?}",
            repaired.per_worker_iters
        );

        let mut broken = crashed.clone();
        broken.exp.faults.repair = false;
        let no_repair = super::run_until(&broken, Some(budget));
        // the group that drafted the dead rank never arms: its members
        // hang forever holding nothing but their Ready state, while the
        // dead rank's locks freeze everyone the GG packed with it
        let max_live = (0..16)
            .filter(|&w| w != 7)
            .map(|w| no_repair.per_worker_iters[w])
            .max()
            .unwrap();
        let frozen = (0..16)
            .filter(|&w| w != 7 && no_repair.per_worker_iters[w] < max_live / 2)
            .count();
        assert!(
            frozen >= 1,
            "no survivor got stuck behind the dead rank: {:?}",
            no_repair.per_worker_iters
        );
        assert!(
            no_repair.total_iters < repaired.total_iters,
            "unrepaired cluster must fall behind at equal time: {} vs {}",
            no_repair.total_iters,
            repaired.total_iters
        );
    }

    #[test]
    fn pair_cluster_deadlocks_without_repair_and_survives_with() {
        use crate::cluster::CrashEvent;
        // 2 workers: once worker 1 crashes inside the armed pair group,
        // worker 0 has no event left — the full AD-PSGD-style deadlock.
        let mut p = params(AlgoKind::RipplesSmart);
        p.exp.cluster.n_nodes = 1;
        p.exp.cluster.workers_per_node = 2;
        p.exp.algo.group_size = 2;
        p.exp.train.max_iters = 40;
        p.exp.cluster.hetero.crashes =
            vec![CrashEvent { worker: 1, at_iter: 5, rejoin_after_secs: None }];
        let mut broken = p.clone();
        broken.exp.faults.repair = false;
        let res = run(&broken);
        assert!(res.deadlocked, "pair cluster must fully deadlock without repair");
        assert!(res.total_iters < 40 * 2);
        // with repair the survivor syncs solo-skips and finishes its budget
        let res = run(&p);
        assert!(!res.deadlocked);
        assert_eq!(res.deaths, 1);
        assert!(
            res.per_worker_iters[0] > 5,
            "survivor must keep training: {:?}",
            res.per_worker_iters
        );
    }

    #[test]
    fn rejoined_worker_is_drafted_again() {
        use crate::cluster::CrashEvent;
        let mut p = params(AlgoKind::RipplesSmart);
        p.exp.train.max_iters = 120;
        p.exp.cluster.hetero.crashes =
            vec![CrashEvent { worker: 7, at_iter: 20, rejoin_after_secs: Some(3.0) }];
        let res = run(&p);
        assert_eq!(res.deaths, 1);
        assert_eq!(res.rejoins, 1);
        assert!(!res.deadlocked);
        assert!(
            res.per_worker_iters[7] > 20,
            "rejoined worker must iterate again: {:?}",
            res.per_worker_iters
        );
        // the restored rank was drafted by other initiators post-rejoin:
        // its last draft falls in the post-crash request stream
        assert!(
            res.drafts[7] > 0 && res.last_drafted_request[7] > 0,
            "rejoined rank never drafted: drafts {:?}",
            res.drafts
        );
        assert!(
            res.gg_requests - res.last_drafted_request[7] < res.gg_requests / 2,
            "rejoined rank not drafted in the later half of the run: last {} of {}",
            res.last_drafted_request[7],
            res.gg_requests
        );
    }

    #[test]
    fn crash_schedules_are_deterministic() {
        use crate::cluster::CrashEvent;
        let mut p = params(AlgoKind::RipplesSmart);
        p.exp.train.max_iters = 100;
        p.exp.cluster.hetero.crashes =
            vec![CrashEvent { worker: 3, at_iter: 15, rejoin_after_secs: Some(2.0) }];
        let a = run(&p);
        let b = run(&p);
        assert_eq!(a.final_time.to_bits(), b.final_time.to_bits());
        assert_eq!(a.total_iters, b.total_iters);
        assert_eq!(a.per_worker_iters, b.per_worker_iters);
        assert_eq!(a.deaths, b.deaths);
        assert_eq!(a.rejoins, b.rejoins);
        assert_eq!(a.groups_aborted, b.groups_aborted);
        assert_eq!(a.trace.len(), b.trace.len());
        for (x, y) in a.trace.iter().zip(b.trace.iter()) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        }
    }

    #[test]
    fn models_converge_toward_consensus() {
        // Spectral-gap consequence: replicas drift together over time.
        let p = params(AlgoKind::RipplesSmart);
        let res = run(&p);
        let _ = res;
        let mut st = p.make_state();
        // replay a short schedule manually to measure disagreement decay
        let mut rng = Pcg32::new(9);
        let mut gg = GroupGenerator::new(GgConfig::smart(16, 4, 3, 8));
        let disagreement = |st: &crate::sim::TrainState| -> f64 {
            let n = st.models[0].len();
            let mut mean = vec![0.0f64; n];
            for m in &st.models {
                for (s, &v) in mean.iter_mut().zip(m.iter()) {
                    *s += v as f64;
                }
            }
            for s in mean.iter_mut() {
                *s /= st.models.len() as f64;
            }
            st.models
                .iter()
                .map(|m| {
                    m.iter()
                        .zip(mean.iter())
                        .map(|(&v, &mu)| (v as f64 - mu).powi(2))
                        .sum::<f64>()
                })
                .sum::<f64>()
        };
        for w in 0..16 {
            st.local_step(w, 0);
        }
        let d0 = disagreement(&st);
        // run a few GD rounds of averaging only
        for round in 0..6 {
            let (_, armed) = gg.request(round % 16, &mut rng);
            for g in &armed {
                st.preduce(&g.members);
            }
            for g in armed {
                gg.complete(g.id);
            }
        }
        let d1 = disagreement(&st);
        assert!(d1 < d0 * 0.8, "disagreement {d0} -> {d1} did not contract");
    }
}
