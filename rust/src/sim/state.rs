//! Shared training state for all simulation engines: per-worker model
//! replicas (real math), loss evaluation, trace recording, termination.

use crate::collectives;
use crate::collectives::codec::WireCodec;
use crate::model::{loss_only, sgd_step, Dataset, MlpScratch, MlpSpec};

/// One point on the loss curve.
#[derive(Debug, Clone, Copy)]
pub struct TracePoint {
    /// Virtual wall-clock seconds.
    pub time: f64,
    /// Average completed iterations per worker.
    pub avg_iter: f64,
    /// Loss of the ensemble-averaged model on the eval set.
    pub loss: f64,
}

/// Simulation outcome (consumed by the figure harnesses).
#[derive(Debug, Clone, Default)]
pub struct SimResult {
    pub algo: String,
    pub trace: Vec<TracePoint>,
    pub final_time: f64,
    pub total_iters: u64,
    pub per_worker_iters: Vec<u64>,
    /// Sum over workers of time spent computing.
    pub compute_time: f64,
    /// Sum over workers of *exposed* synchronization time (wait +
    /// transfer the worker actually blocked on). Without overlap this is
    /// the whole sync cost.
    pub sync_time: f64,
    /// Sum over workers of sync cost *hidden* behind stale compute by
    /// the pipelined-overlap model (`Experiment::overlap`); 0.0 when
    /// overlap is off.
    pub hidden_sync_time: f64,
    /// Per-stage exposed waits of the staged step pipeline
    /// (`Experiment::pipeline`). With the lockstep default, every step
    /// fully exposes its load segment (`load_wait_time` = total load
    /// time, `compute_wait_time` = 0); with prefetch, only the
    /// per-step `max(load, compute)` bottleneck is exposed — the
    /// shorter stage's remainder shows up in the *other* stage's wait.
    pub load_wait_time: f64,
    /// Virtual seconds the loader stage idled waiting for compute
    /// (steps where compute was the pipeline bottleneck).
    pub compute_wait_time: f64,
    /// The reconcile stage's exposed wait: identical to `sync_time`,
    /// reported under its stage name so the three-stage breakdown is
    /// complete (load / compute / reconcile).
    pub reconcile_wait_time: f64,
    pub time_to_target: Option<f64>,
    pub avg_iters_to_target: Option<f64>,
    pub conflicts: u64,
    pub gg_requests: u64,
    pub comm_cache_hits: u64,
    pub comm_cache_misses: u64,
    /// Final measured per-worker EWMA step seconds from the GG speed
    /// table (empty for engines without a GG; 0.0 = never observed).
    pub measured_speeds: Vec<f64>,
    /// Per-worker drafts into groups created by *other* initiators.
    pub drafts: Vec<u64>,
    /// `gg_requests` value at each worker's most recent such draft.
    pub last_drafted_request: Vec<u64>,
    /// `gg_requests` value when the first scheduled slowdown change
    /// (`cluster::SlowdownEvent`) took effect; None = none fired.
    pub onset_request: Option<u64>,
    /// Crashes that fired (`cluster::CrashEvent`).
    pub deaths: u64,
    /// Groups torn down by failure repair.
    pub groups_aborted: u64,
    /// Crashed workers that checkpoint-restored and rejoined.
    pub rejoins: u64,
    /// The run ended in a stall: every live worker blocked forever on a
    /// group naming a crashed rank — the no-repair failure mode
    /// (`[faults] repair = false`) that `fig failures` measures.
    pub deadlocked: bool,
    /// Total bytes the cost model put on the wire across all collectives
    /// (`2(p-1)` chunk transfers per P-Reduce, under the configured
    /// [`WireCodec`]'s bytes-per-element — `fig wire`'s bytes axis).
    pub bytes_on_wire: u64,
}

impl SimResult {
    /// Mean wall-clock seconds per (per-worker) iteration.
    pub fn per_iter_time(&self) -> f64 {
        if self.total_iters == 0 {
            return 0.0;
        }
        self.final_time / (self.total_iters as f64 / self.per_worker_iters.len() as f64)
    }

    /// Fraction of worker-time spent in *exposed* synchronization
    /// (Fig. 2b's metric; with overlap enabled, hidden sync is excluded
    /// because the worker was computing through it).
    pub fn sync_fraction(&self) -> f64 {
        let total = self.compute_time + self.sync_time;
        if total == 0.0 {
            0.0
        } else {
            self.sync_time / total
        }
    }

    /// Share of the total sync cost the overlap pipeline hid behind
    /// compute (0.0 when overlap is off or nothing was hidden).
    pub fn hidden_sync_share(&self) -> f64 {
        let total = self.sync_time + self.hidden_sync_time;
        if total == 0.0 {
            0.0
        } else {
            self.hidden_sync_time / total
        }
    }
}

/// Per-worker replicas + the real-math SGD/eval plumbing.
pub struct TrainState {
    pub spec: MlpSpec,
    pub dataset: Dataset,
    pub models: Vec<Vec<f32>>,
    pub batch: usize,
    pub lr: f32,
    scratch: MlpScratch,
    avg_scratch: Vec<f32>,
    eval_x: Vec<f32>,
    eval_y: Vec<usize>,
    pub trace: Vec<TracePoint>,
    /// Smoothed loss (EMA) for target detection.
    smoothed: Option<f64>,
    pub loss_target: Option<f64>,
    pub hit_time: Option<f64>,
    pub hit_avg_iter: Option<f64>,
    seed: u64,
    /// Non-IID skew: probability a sample comes from the worker's primary
    /// class (0 = IID).
    data_bias: f64,
    class_index: Vec<Vec<usize>>,
}

impl TrainState {
    pub fn new(
        spec: MlpSpec,
        dataset: Dataset,
        n_workers: usize,
        batch: usize,
        lr: f32,
        loss_target: Option<f64>,
        seed: u64,
    ) -> Self {
        Self::with_bias(spec, dataset, n_workers, batch, lr, loss_target, seed, 0.0)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn with_bias(
        spec: MlpSpec,
        dataset: Dataset,
        n_workers: usize,
        batch: usize,
        lr: f32,
        loss_target: Option<f64>,
        seed: u64,
        data_bias: f64,
    ) -> Self {
        let init = spec.init(seed);
        let (eval_x, eval_y) = dataset.eval_set(512);
        let class_index = dataset.class_index();
        Self {
            models: vec![init; n_workers],
            spec,
            dataset,
            batch,
            lr,
            scratch: MlpScratch::new(),
            avg_scratch: Vec::new(),
            eval_x,
            eval_y,
            trace: Vec::new(),
            smoothed: None,
            loss_target,
            hit_time: None,
            hit_avg_iter: None,
            seed,
            data_bias,
            class_index,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.models.len()
    }

    /// One local SGD iteration for `worker` (tag makes batches distinct
    /// across workers and iterations but deterministic per seed). With
    /// `data_bias > 0` the worker draws from its non-IID shard (primary
    /// class `worker % classes`).
    pub fn local_step(&mut self, worker: usize, iter: u64) -> f64 {
        let tag = self
            .seed
            .wrapping_mul(0x517C_C1B7_2722_0A95)
            .wrapping_add((worker as u64) << 32)
            .wrapping_add(iter);
        let (x, y) = if self.data_bias > 0.0 {
            self.dataset.batch_biased(
                tag,
                self.batch,
                worker % self.spec.classes,
                self.data_bias,
                &self.class_index,
            )
        } else {
            self.dataset.batch(tag, self.batch)
        };
        sgd_step(&self.spec, &mut self.models[worker], &x, &y, self.lr, &mut self.scratch)
    }

    /// F^G under a wire codec: each member's replica first takes the
    /// codec's encode→decode precision loss (per ring-chunk granularity,
    /// `p` chunks for a `p`-member group — the same quantization ranges
    /// the TCP data plane uses), then the group averages. `Fp32` is
    /// exactly [`TrainState::preduce`]. A first-order model: the real
    /// ring also re-quantizes partial sums per hop, so this slightly
    /// *under*-states q8 noise, which the differential ring tests bound
    /// separately.
    pub fn preduce_coded(&mut self, group: &[usize], wire: WireCodec) {
        if wire != WireCodec::Fp32 {
            let p = group.len().max(1);
            for &g in group {
                let n = self.models[g].len();
                for c in 0..p {
                    let (lo, hi) = crate::collectives::pipeline::shard_bounds(n, p, c);
                    wire.roundtrip_inplace(&mut self.models[g][lo..hi]);
                }
            }
        }
        self.preduce(group);
    }

    /// Apply F^G: average the models of `group` in place.
    pub fn preduce(&mut self, group: &[usize]) {
        debug_assert!(group.windows(2).all(|w| w[0] < w[1]), "group must be sorted");
        // Split the borrow: collect raw pointers safely via split_at_mut
        // dance — simplest correct approach: take slices by index order.
        let mut refs: Vec<&mut [f32]> = Vec::with_capacity(group.len());
        let mut rest: &mut [Vec<f32>] = &mut self.models;
        let mut offset = 0usize;
        for &g in group {
            let idx = g - offset;
            let (head, tail) = rest.split_at_mut(idx + 1);
            refs.push(head[idx].as_mut_slice());
            rest = tail;
            offset = g + 1;
        }
        collectives::preduce_mean_inplace(&mut refs, &mut self.avg_scratch);
    }

    /// Average ALL models (the All-Reduce/PS step).
    pub fn global_average(&mut self) {
        let group: Vec<usize> = (0..self.n_workers()).collect();
        self.preduce(&group);
    }

    /// Training loss as a distributed system logs it: the mean of
    /// per-replica losses (sampled over up to 4 replicas for speed).
    ///
    /// This is deliberately NOT the loss of the ensemble-mean model: the
    /// averaged iterate hides replica drift entirely (local-SGD folklore),
    /// while per-replica loss exposes the statistical-efficiency cost of
    /// infrequent or less-random synchronization — the effect Figs. 16/18
    /// measure.
    pub fn global_loss(&mut self) -> f64 {
        let n_models = self.models.len();
        let stride = n_models.div_ceil(4).max(1);
        let mut total = 0.0;
        let mut count = 0;
        let mut w = 0;
        while w < n_models {
            total += loss_only(&self.spec, &self.models[w], &self.eval_x, &self.eval_y);
            count += 1;
            w += stride;
        }
        total / count as f64
    }

    /// Loss of the ensemble-mean model (consensus view; used by Fig. 20's
    /// final-accuracy reporting).
    pub fn consensus_loss(&mut self) -> f64 {
        let n = self.models[0].len();
        self.avg_scratch.clear();
        self.avg_scratch.resize(n, 0.0);
        for m in &self.models {
            for (s, &v) in self.avg_scratch.iter_mut().zip(m.iter()) {
                *s += v;
            }
        }
        let inv = 1.0 / self.models.len() as f32;
        for s in self.avg_scratch.iter_mut() {
            *s *= inv;
        }
        loss_only(&self.spec, &self.avg_scratch, &self.eval_x, &self.eval_y)
    }

    /// Record a trace point; returns true if the loss target was just hit.
    pub fn record(&mut self, time: f64, avg_iter: f64) -> bool {
        let loss = self.global_loss();
        self.trace.push(TracePoint { time, avg_iter, loss });
        let s = match self.smoothed {
            Some(prev) => 0.5 * prev + 0.5 * loss,
            None => loss,
        };
        self.smoothed = Some(s);
        if self.hit_time.is_none() {
            if let Some(target) = self.loss_target {
                if s <= target {
                    self.hit_time = Some(time);
                    self.hit_avg_iter = Some(avg_iter);
                    return true;
                }
            }
        }
        false
    }

    pub fn done(&self) -> bool {
        self.hit_time.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(n: usize) -> TrainState {
        let spec = MlpSpec::tiny();
        let ds = Dataset::gaussian_mixture(spec.in_dim, spec.classes, 256, 7);
        TrainState::new(spec, ds, n, 32, 0.1, Some(0.05), 1)
    }

    #[test]
    fn preduce_coded_fp32_is_exact_and_q8_stays_close() {
        let mut a = state(4);
        let mut b = state(4);
        for w in 0..4 {
            a.local_step(w, 0);
            b.local_step(w, 0);
        }
        a.preduce(&[0, 2]);
        b.preduce_coded(&[0, 2], WireCodec::Fp32);
        assert_eq!(a.models[0], b.models[0], "fp32 coded path must be exact");
        // q8: members end equal (same codec view averaged), near fp32
        b.preduce_coded(&[1, 3], WireCodec::Q8);
        a.preduce(&[1, 3]);
        assert_eq!(b.models[1], b.models[3]);
        let range = a.models[1]
            .iter()
            .fold((f32::MAX, f32::MIN), |(lo, hi), &v| (lo.min(v), hi.max(v)));
        let tol = (range.1 - range.0) / 125.0 + 1e-5;
        for (x, y) in a.models[1].iter().zip(b.models[1].iter()) {
            assert!((x - y).abs() <= tol, "{x} vs {y} (tol {tol})");
        }
    }

    #[test]
    fn preduce_makes_group_models_equal() {
        let mut st = state(4);
        for w in 0..4 {
            st.local_step(w, 0);
        }
        st.preduce(&[1, 3]);
        assert_eq!(st.models[1], st.models[3]);
        assert_ne!(st.models[0], st.models[1]);
    }

    #[test]
    fn preduce_nonadjacent_group_indices() {
        let mut st = state(8);
        for w in 0..8 {
            st.local_step(w, 0);
            st.local_step(w, 1);
        }
        let before_sum: f64 = [0usize, 4, 7]
            .iter()
            .map(|&w| st.models[w].iter().map(|&v| v as f64).sum::<f64>())
            .sum();
        st.preduce(&[0, 4, 7]);
        assert_eq!(st.models[0], st.models[4]);
        assert_eq!(st.models[4], st.models[7]);
        let after_sum: f64 = [0usize, 4, 7]
            .iter()
            .map(|&w| st.models[w].iter().map(|&v| v as f64).sum::<f64>())
            .sum();
        assert!((before_sum - after_sum).abs() < 1e-2, "mass not conserved");
    }

    #[test]
    fn local_steps_deterministic() {
        let mut a = state(2);
        let mut b = state(2);
        let la = a.local_step(0, 5);
        let lb = b.local_step(0, 5);
        assert_eq!(la, lb);
        assert_eq!(a.models[0], b.models[0]);
    }

    #[test]
    fn global_average_then_loss_decreases_with_training() {
        let mut st = state(2);
        let l0 = st.global_loss();
        for it in 0..40 {
            st.local_step(0, it);
            st.local_step(1, it);
            st.global_average();
        }
        let l1 = st.global_loss();
        assert!(l1 < l0, "{l0} -> {l1}");
    }

    #[test]
    fn record_hits_target() {
        let mut st = state(2);
        st.loss_target = Some(1e9); // absurdly easy
        assert!(st.record(1.0, 1.0));
        assert_eq!(st.hit_time, Some(1.0));
        assert!(st.done());
    }
}
