//! Barrier-style baselines: All-Reduce, Parameter Server, D-PSGD.
//!
//! These need no event queue — per-iteration timing is a closed-form
//! recurrence over worker finish times:
//!
//! * All-Reduce / PS: a *global* barrier; the round ends when the slowest
//!   worker finishes compute, plus the collective / server round cost.
//!   This is precisely why one 5x-slow worker drags the whole cluster
//!   (Fig. 1, Fig. 19).
//! * D-PSGD: each worker barriers only with its ring neighbors, so slow
//!   workers stall their neighborhood; the stall still propagates around
//!   the ring at one hop per iteration.

use crate::cluster::{calibration, ComputeTimer};
use crate::comm::CostModel;
use crate::config::AlgoKind;

use super::state::SimResult;
use super::SimParams;

pub fn run(params: &SimParams) -> SimResult {
    run_until(params, None)
}

pub fn run_until(params: &SimParams, time_budget: Option<f64>) -> SimResult {
    let exp = &params.exp;
    let n = exp.cluster.n_workers();
    let cost = CostModel::from_cluster(&exp.cluster);
    let mut timer = ComputeTimer::new(
        params.compute_base,
        exp.cluster.hetero.clone(),
        n,
        exp.train.seed,
    );
    let mut st = params.make_state();
    let kind = exp.algo.kind;
    let bytes = params.model_bytes;
    let section = exp.algo.section_len.max(1);

    // Per-worker local clocks (D-PSGD); AR/PS collapse to one clock.
    let mut t = vec![0.0f64; n];
    let mut compute_total = 0.0f64;
    let mut sync_total = 0.0f64;
    let all: Vec<usize> = (0..n).collect();

    let hetero = &exp.cluster.hetero;
    let ps_shards = exp.algo.ps_shards.max(1);
    // Per-iteration sync cost: bandwidth throttles (`cluster::
    // BandwidthEvent`) reshape the collective exactly as in the Ripples
    // engine, so heterogeneous-bandwidth comparisons (`fig paper`) are
    // apples-to-apples. With no events every divisor is 1.0 and the
    // throttled costs are bit-identical to the classic ones.
    let sync_cost = |k: AlgoKind, iter: u64| -> f64 {
        let div: Vec<f64> = (0..n).map(|w| hetero.bandwidth_factor_at(w, iter)).collect();
        match k {
            AlgoKind::AllReduce => {
                // same placement-shape dispatch as the Ripples engine:
                // `[topology] shape = "flat"` (default) is bit-identical
                // to the classic throttled ring
                super::preduce_sync_cost(&cost, &exp.topology, &all, bytes, &div)
                    + calibration::ALLREDUCE_OVERHEAD
            }
            AlgoKind::ParameterServer => {
                cost.ps_round_sharded(n, bytes, ps_shards, &div) + calibration::PS_OVERHEAD
            }
            AlgoKind::DPsgd => {
                // two neighbor exchanges, worst-case inter-node, at the
                // cluster's slowest link
                let worst = div.iter().cloned().fold(1.0, f64::max);
                cost.pairwise_avg_throttled(0, n / 2, bytes, 0.0, worst)
                    + calibration::PREDUCE_OVERHEAD
            }
            _ => unreachable!("rounds engine got {k:?}"),
        }
    };

    st.record(0.0, 0.0);
    let mut iter: u64 = 0;
    let max_iters = exp.train.max_iters as u64;
    'outer: while iter < max_iters && !st.done() {
        // local compute everywhere (real math)
        let mut finish = vec![0.0f64; n];
        for w in 0..n {
            let c = timer.next_compute(w);
            st.local_step(w, iter);
            finish[w] = t[w] + c;
            compute_total += c;
        }
        iter += 1;
        let do_sync = iter % section as u64 == 0;
        match kind {
            AlgoKind::AllReduce | AlgoKind::ParameterServer => {
                let barrier = finish.iter().cloned().fold(0.0, f64::max);
                let s = if do_sync { sync_cost(kind, iter) } else { 0.0 };
                if do_sync {
                    st.global_average();
                }
                // every worker waits from its own finish to barrier + sync
                for w in 0..n {
                    sync_total += barrier - finish[w] + s;
                    t[w] = barrier + s;
                }
            }
            AlgoKind::DPsgd => {
                if do_sync {
                    // neighborhood averaging on a ring (W with 1/3 weights):
                    // new_x[w] = mean(x[w-1], x[w], x[w+1])
                    let snapshot = st.models.clone();
                    for w in 0..n {
                        let l = (w + n - 1) % n;
                        let r = (w + 1) % n;
                        let model = &mut st.models[w];
                        for i in 0..model.len() {
                            model[i] =
                                (snapshot[l][i] + snapshot[w][i] + snapshot[r][i]) / 3.0;
                        }
                    }
                    let s = sync_cost(kind, iter);
                    let mut t_next = vec![0.0f64; n];
                    for w in 0..n {
                        let l = (w + n - 1) % n;
                        let r = (w + 1) % n;
                        let ready = finish[w].max(finish[l]).max(finish[r]);
                        sync_total += ready - finish[w] + s;
                        t_next[w] = ready + s;
                    }
                    t = t_next;
                } else {
                    t = finish;
                }
            }
            _ => unreachable!(),
        }
        if iter % exp.train.eval_every as u64 == 0 {
            let now = t.iter().cloned().fold(0.0, f64::max);
            st.record(now, iter as f64);
        }
        if let Some(budget) = time_budget {
            if t.iter().cloned().fold(0.0, f64::max) > budget {
                break 'outer;
            }
        }
    }

    let final_time = t.iter().cloned().fold(0.0, f64::max);
    if st.trace.last().map(|tp| tp.avg_iter) != Some(iter as f64) {
        st.record(final_time, iter as f64);
    }
    SimResult {
        algo: kind.name().to_string(),
        final_time,
        total_iters: iter * n as u64,
        per_worker_iters: vec![iter; n],
        compute_time: compute_total,
        sync_time: sync_total,
        time_to_target: st.hit_time,
        avg_iters_to_target: st.hit_avg_iter,
        trace: st.trace,
        conflicts: 0,
        gg_requests: 0,
        comm_cache_hits: 0,
        comm_cache_misses: 0,
        ..SimResult::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Experiment;
    use crate::model::MlpSpec;

    fn params(kind: AlgoKind) -> SimParams {
        let mut exp = Experiment::default();
        exp.algo.kind = kind;
        exp.train.max_iters = 40;
        exp.train.eval_every = 10;
        exp.train.loss_target = None;
        let mut p = SimParams::vgg16_defaults(exp);
        p.spec = MlpSpec::tiny();
        p.dataset_size = 256;
        p.batch = 32;
        p
    }

    #[test]
    fn allreduce_topology_anchor_halves_blind_sync() {
        // The fig-topo acceptance anchor: 8 workers as 2 machines of 4
        // behind a constrained 1.5 GB/s uplink, VGG-size transfers, one
        // global collective per iteration. The barrier schedule is fixed
        // independent of virtual time, so every placement shape runs
        // bit-identical arithmetic (equal loss); the two-level collective
        // must at least halve the placement-blind flat ring's sync time,
        // with the bandwidth-ordered flat ring in between.
        use crate::config::SyncShape;
        let mk = |shape: SyncShape| {
            let mut p = params(AlgoKind::AllReduce);
            p.exp.cluster.n_nodes = 2;
            p.exp.cluster.workers_per_node = 4;
            p.exp.cluster.link.inter_bw = 1.5e9;
            p.exp.topology.shape = shape;
            p.model_bytes = 38_720_000;
            run(&p)
        };
        let flat = mk(SyncShape::Flat);
        let blind = mk(SyncShape::FlatBlind);
        let ordered = mk(SyncShape::FlatOrdered);
        let hier = mk(SyncShape::Hier);
        let loss = flat.trace.last().unwrap().loss;
        for (name, r) in [("blind", &blind), ("ordered", &ordered), ("hier", &hier)] {
            assert_eq!(r.total_iters, flat.total_iters, "{name}");
            assert_eq!(
                r.trace.last().unwrap().loss.to_bits(),
                loss.to_bits(),
                "{name}: placement shape changed the arithmetic"
            );
        }
        assert!(
            blind.sync_time >= 2.0 * hier.sync_time,
            "two-level must halve blind-flat sync: {} vs {}",
            blind.sync_time,
            hier.sync_time
        );
        assert!(ordered.sync_time > hier.sync_time);
        assert!(blind.sync_time > ordered.sync_time);
        assert!(hier.final_time < blind.final_time);
        // node-major order on 2 machines is the degenerate no-op: the
        // uplink model and the classic worst-edge model coincide
        assert!((ordered.sync_time - flat.sync_time).abs() < 1e-6 * flat.sync_time);
    }

    #[test]
    fn allreduce_faster_than_ps_per_iteration() {
        let ar = run(&params(AlgoKind::AllReduce));
        let ps = run(&params(AlgoKind::ParameterServer));
        assert!(ar.per_iter_time() < ps.per_iter_time());
    }

    #[test]
    fn allreduce_models_stay_identical() {
        let p = params(AlgoKind::AllReduce);
        let _ = run(&p); // run() consumes state internally; re-run manually
        // rebuild and check invariant directly
        let mut st = p.make_state();
        for it in 0..5 {
            for w in 0..st.n_workers() {
                st.local_step(w, it);
            }
            st.global_average();
        }
        for w in 1..st.n_workers() {
            assert_eq!(st.models[0], st.models[w]);
        }
    }

    #[test]
    fn slow_worker_drags_allreduce_proportionally() {
        let mut p = params(AlgoKind::AllReduce);
        let base = run(&p).final_time;
        p.exp.cluster.hetero.slow_worker = Some((7, 5.0));
        let slow = run(&p).final_time;
        // compute dominates at these settings; 5x slow worker should push
        // total time up by at least 2x (global barrier effect)
        assert!(slow > base * 2.0, "base {base} slow {slow}");
    }

    #[test]
    fn dpsgd_tolerates_slowdown_better_than_allreduce() {
        let mut pa = params(AlgoKind::AllReduce);
        let mut pd = params(AlgoKind::DPsgd);
        pa.exp.cluster.hetero.slow_worker = Some((0, 5.0));
        pd.exp.cluster.hetero.slow_worker = Some((0, 5.0));
        let a = run(&pa);
        let d = run(&pd);
        // D-PSGD's fast workers keep running ahead of the slow one's
        // neighborhood, so it finishes the same #iters sooner.
        assert!(d.final_time < a.final_time, "{} vs {}", d.final_time, a.final_time);
    }

    #[test]
    fn ps_rounds_are_deterministic() {
        // Same idiom as the crash-schedule determinism test: two fresh
        // invocations must agree bit-for-bit — this is what pins the PS
        // rows of BENCH_paper.json to their committed values.
        let p = params(AlgoKind::ParameterServer);
        let a = run(&p);
        let b = run(&p);
        assert_eq!(a.final_time, b.final_time);
        assert_eq!(a.total_iters, b.total_iters);
        assert_eq!(a.sync_time, b.sync_time);
        assert_eq!(a.trace.len(), b.trace.len());
        for (ta, tb) in a.trace.iter().zip(&b.trace) {
            assert_eq!(ta.loss, tb.loss);
            assert_eq!(ta.time, tb.time);
        }
    }

    #[test]
    fn ps_sharding_cuts_the_sync_bill() {
        let p1 = params(AlgoKind::ParameterServer);
        let mut p4 = p1.clone();
        p4.exp.algo.ps_shards = 4;
        let r1 = run(&p1);
        let r4 = run(&p4);
        // identical iteration schedule, strictly cheaper sync per round
        assert_eq!(r1.total_iters, r4.total_iters);
        assert!(r4.sync_time < r1.sync_time, "{} vs {}", r4.sync_time, r1.sync_time);
        assert!(r4.final_time < r1.final_time, "{} vs {}", r4.final_time, r1.final_time);
    }

    #[test]
    fn bandwidth_throttle_slows_barrier_baselines() {
        use crate::cluster::BandwidthEvent;
        for kind in [AlgoKind::AllReduce, AlgoKind::ParameterServer] {
            let base = run(&params(kind));
            let mut p = params(kind);
            p.exp.cluster.hetero.bandwidth =
                vec![BandwidthEvent { worker: 1, factor: 8.0, start_iter: 0 }];
            let slow = run(&p);
            // compute draws are untouched by bandwidth events, so the
            // only change is a strictly larger sync term every round
            assert_eq!(base.total_iters, slow.total_iters, "{kind:?}");
            assert!(
                slow.final_time > base.final_time,
                "{kind:?}: {} vs {}",
                slow.final_time,
                base.final_time
            );
        }
    }

    #[test]
    fn section_length_reduces_sync_share() {
        let mut p1 = params(AlgoKind::AllReduce);
        p1.exp.train.max_iters = 32;
        let mut p4 = p1.clone();
        p4.exp.algo.section_len = 4;
        let r1 = run(&p1);
        let r4 = run(&p4);
        assert!(r4.sync_fraction() < r1.sync_fraction());
        assert!(r4.final_time < r1.final_time);
    }
}
