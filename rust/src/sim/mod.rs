//! Virtual-time simulation of the distributed training cluster.
//!
//! Real SGD math (pure-Rust MLP replicas) + calibrated communication /
//! compute costs (see `cluster::calibration`) = loss curves whose x-axis
//! can be either iterations (statistical efficiency, Figs. 16/18) or
//! virtual seconds (wall-clock efficiency, Figs. 1/17/19/20), reproducing
//! the paper's trade-off analysis on one laptop-scale testbed.
//!
//! Engines:
//! * [`rounds`]  — barrier-style algorithms: All-Reduce, Parameter Server,
//!   D-PSGD (synchronous neighborhood averaging).
//! * [`adpsgd`]  — event-driven AD-PSGD with the bipartite active/passive
//!   protocol and pairwise atomic averaging.
//! * [`ripples`] — event-driven Ripples: GG-scheduled (random or smart)
//!   and static-scheduled P-Reduce groups.

pub mod adpsgd;
pub mod events;
pub mod ripples;
pub mod rounds;
pub mod state;

pub use state::{SimResult, TracePoint, TrainState};

use crate::cluster::calibration;
use crate::comm::CostModel;
use crate::config::{AlgoKind, Experiment, SyncShape, TopologyConfig};
use crate::model::{Dataset, MlpSpec};

/// Everything a simulation run needs.
#[derive(Debug, Clone)]
pub struct SimParams {
    pub exp: Experiment,
    /// MLP shape used for the real math.
    pub spec: MlpSpec,
    pub dataset_size: usize,
    pub batch: usize,
    /// Homogeneous per-iteration compute seconds (calibrated).
    pub compute_base: f64,
    /// Model bytes moved by synchronization (calibrated; decoupled from
    /// the MLP's real size so paper-scale costs apply).
    pub model_bytes: usize,
    /// Non-IID data skew per worker (probability of drawing from the
    /// worker's primary class). 0 = IID; the figure harnesses use 0.6 so
    /// synchronization frequency/randomness has a statistical effect.
    pub data_bias: f64,
    /// Coordinator CPU seconds per GG RPC for the contention model
    /// ([`crate::comm::CostModel::gg_rtt_contended`]). 0.0 (default)
    /// disables contention — bit-identical to the pre-scale model.
    pub gg_service: f64,
    /// Independently lockable GG shards the contention model divides the
    /// outstanding-RPC queue across. Ignored while `gg_service == 0`.
    pub gg_shards: usize,
}

impl SimParams {
    /// Paper-calibrated defaults: VGG-16/CIFAR-10 on the 4x4 GTX cluster.
    pub fn vgg16_defaults(exp: Experiment) -> Self {
        Self {
            exp,
            spec: MlpSpec::default_paper(),
            dataset_size: 4096,
            batch: 128,
            compute_base: calibration::VGG16_COMPUTE,
            model_bytes: calibration::VGG16_BYTES,
            data_bias: 0.0,
            gg_service: 0.0,
            gg_shards: 1,
        }
    }

    /// ResNet-50/ImageNet-calibrated variant (Fig. 20).
    pub fn resnet50_defaults(exp: Experiment) -> Self {
        Self {
            exp,
            spec: MlpSpec { in_dim: 64, hidden: vec![256, 256], classes: 100 },
            dataset_size: 16384,
            batch: 32,
            compute_base: calibration::RESNET50_COMPUTE,
            model_bytes: calibration::RESNET50_BYTES,
            data_bias: 0.0,
            gg_service: 0.0,
            gg_shards: 1,
        }
    }

    pub fn make_state(&self) -> TrainState {
        let ds = Dataset::gaussian_mixture(
            self.spec.in_dim,
            self.spec.classes,
            self.dataset_size,
            self.exp.train.seed ^ 0xDA7A,
        );
        TrainState::with_bias(
            self.spec.clone(),
            ds,
            self.exp.cluster.n_workers(),
            self.batch,
            self.exp.train.lr,
            self.exp.train.loss_target,
            self.exp.train.seed,
            self.data_bias,
        )
    }
}

/// One collective's virtual cost under the configured placement shape
/// (`[topology]`, DESIGN.md §Perf "Hierarchical P-Reduce"). Shared by
/// the Ripples engine (per-group P-Reduce) and the rounds engine's
/// global all-reduce barrier. The `flat` default is the call both
/// engines always made — bit-identical; the other shapes swap in the
/// shared-uplink serialization and two-level models so `fig topo` can
/// sweep them.
pub(crate) fn preduce_sync_cost(
    cost: &CostModel,
    topo: &TopologyConfig,
    members: &[usize],
    wire_bytes: usize,
    bw: &[f64],
) -> f64 {
    let per = topo.per_machine(cost.workers_per_node);
    match topo.shape {
        SyncShape::Flat => cost.ring_allreduce_throttled(members, wire_bytes, bw),
        SyncShape::FlatBlind => {
            cost.ring_allreduce_uplink(members, wire_bytes, bw, per, true)
        }
        SyncShape::FlatOrdered => {
            cost.ring_allreduce_uplink(members, wire_bytes, bw, per, false)
        }
        SyncShape::Hier => cost.hierarchical(members, wire_bytes, bw, per),
    }
}

/// Run the experiment with the algorithm selected in `params.exp.algo`.
pub fn run(params: &SimParams) -> SimResult {
    params.exp.validate().expect("invalid experiment");
    match params.exp.algo.kind {
        AlgoKind::AllReduce | AlgoKind::ParameterServer | AlgoKind::DPsgd => {
            rounds::run(params)
        }
        AlgoKind::AdPsgd => adpsgd::run(params),
        AlgoKind::RipplesStatic | AlgoKind::RipplesRandom | AlgoKind::RipplesSmart => {
            ripples::run(params)
        }
    }
}

/// Convenience: run with a stopping budget in *virtual seconds* instead of
/// iterations (Fig. 20's fixed-10-hour methodology).
pub fn run_time_budget(params: &SimParams, budget_secs: f64) -> SimResult {
    let mut p = params.clone();
    // Derive an iteration cap generously above what the budget allows,
    // then truncate the result at the budget.
    p.exp.train.loss_target = None;
    let est_iter = budget_secs / p.compute_base;
    p.exp.train.max_iters = (est_iter * 4.0) as usize + 10;
    let mut res = run_until(&p, Some(budget_secs));
    res.trace.retain(|tp| tp.time <= budget_secs);
    res
}

pub(crate) fn run_until(params: &SimParams, time_budget: Option<f64>) -> SimResult {
    params.exp.validate().expect("invalid experiment");
    match params.exp.algo.kind {
        AlgoKind::AllReduce | AlgoKind::ParameterServer | AlgoKind::DPsgd => {
            rounds::run_until(params, time_budget)
        }
        AlgoKind::AdPsgd => adpsgd::run_until(params, time_budget),
        AlgoKind::RipplesStatic | AlgoKind::RipplesRandom | AlgoKind::RipplesSmart => {
            ripples::run_until(params, time_budget)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_params(kind: AlgoKind) -> SimParams {
        let mut exp = Experiment::default();
        exp.algo.kind = kind;
        exp.train.max_iters = 60;
        exp.train.eval_every = 10;
        exp.train.loss_target = None;
        let mut p = SimParams::vgg16_defaults(exp);
        p.spec = MlpSpec::tiny();
        p.dataset_size = 512;
        p.batch = 32;
        p
    }

    #[test]
    fn all_algorithms_run_and_learn() {
        for &kind in AlgoKind::all() {
            let p = quick_params(kind);
            let res = run(&p);
            assert!(res.total_iters > 0, "{kind:?} made no progress");
            assert!(res.final_time > 0.0);
            assert!(!res.trace.is_empty(), "{kind:?} produced no trace");
            let first = res.trace.first().unwrap().loss;
            let last = res.trace.last().unwrap().loss;
            assert!(
                last < first,
                "{kind:?} loss did not decrease: {first} -> {last}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let p = quick_params(AlgoKind::RipplesSmart);
        let a = run(&p);
        let b = run(&p);
        assert_eq!(a.total_iters, b.total_iters);
        assert_eq!(a.final_time, b.final_time);
        assert_eq!(a.trace.len(), b.trace.len());
        for (x, y) in a.trace.iter().zip(b.trace.iter()) {
            assert_eq!(x.loss, y.loss);
        }
    }

    #[test]
    fn time_budget_truncates() {
        let p = quick_params(AlgoKind::AllReduce);
        let res = run_time_budget(&p, 3.0);
        assert!(res.trace.iter().all(|tp| tp.time <= 3.0));
        assert!(res.final_time <= 3.0 + 1.0, "final {}", res.final_time);
    }
}
