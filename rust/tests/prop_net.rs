//! Property-based tests of the data-plane wire frames, in the style of
//! `prop_gg.rs`: hand-rolled randomized harness (no proptest in the
//! vendored registry), seeds in panic messages for reproducibility.

use ripples::net::frame::{read_frame, write_frame, Frame};
use ripples::rpc::{Request, Response};
use ripples::util::rng::Pcg32;

const SEEDS: u64 = 60;

fn rand_chunk(rng: &mut Pcg32) -> Frame {
    let count = rng.gen_range(2049);
    let data: Vec<f32> = (0..count)
        .map(|_| {
            // cover exact-bit-pattern extremes, not just uniform draws
            match rng.gen_range(8) {
                0 => 0.0,
                1 => -0.0,
                2 => f32::MIN,
                3 => f32::MAX,
                4 => f32::MIN_POSITIVE,
                _ => rng.gen_f32() * 2e6 - 1e6,
            }
        })
        .collect();
    Frame::Chunk { gid: rng.next_u64(), step: rng.next_u32(), data }
}

/// Every chunk frame survives encode -> decode bit-exactly.
#[test]
fn prop_chunk_roundtrip() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed);
        let frame = rand_chunk(&mut rng);
        let decoded = Frame::decode(&frame.encode())
            .unwrap_or_else(|e| panic!("seed {seed}: decode failed: {e}"));
        // PartialEq on f32 vectors is what we want here: the codec must
        // preserve exact bit patterns (NaN is excluded by construction).
        assert_eq!(decoded, frame, "seed {seed}");
    }
}

/// The encoded size is exactly the header plus 4 bytes per element —
/// nothing hidden, nothing padded (the cost model charges per byte).
#[test]
fn prop_chunk_encoding_size() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed);
        let frame = rand_chunk(&mut rng);
        let Frame::Chunk { ref data, .. } = frame else { unreachable!() };
        assert_eq!(
            frame.encode().len(),
            1 + 8 + 4 + 4 + 4 * data.len(),
            "seed {seed}"
        );
    }
}

/// Any strict prefix of a valid frame must fail to decode (truncation is
/// detected, never silently zero-filled).
#[test]
fn prop_truncation_detected() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed);
        let frame = rand_chunk(&mut rng);
        let buf = frame.encode();
        // a handful of random cut points plus the boundary cases
        let mut cuts = vec![0, 1, buf.len() - 1];
        for _ in 0..8 {
            cuts.push(rng.gen_range(buf.len()));
        }
        for cut in cuts {
            assert!(
                Frame::decode(&buf[..cut]).is_err(),
                "seed {seed}: truncation at {cut}/{} decoded",
                buf.len()
            );
        }
    }
}

/// Appending trailing garbage must fail to decode (frames are
/// length-delimited by the outer transport; slack means corruption).
#[test]
fn prop_trailing_bytes_detected() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed);
        let mut buf = rand_chunk(&mut rng).encode();
        buf.push(rng.next_u32() as u8);
        assert!(Frame::decode(&buf).is_err(), "seed {seed}");
    }
}

/// Streamed frames (length-prefixed over a byte pipe) arrive in order and
/// intact — the mesh's actual on-socket format.
#[test]
fn prop_stream_sequence_roundtrip() {
    for seed in 0..SEEDS / 4 {
        let mut rng = Pcg32::new(seed ^ 0x57EA);
        let frames: Vec<Frame> = (0..rng.gen_range(6) + 1)
            .map(|i| {
                if i == 0 {
                    Frame::Hello { rank: rng.next_u32() }
                } else {
                    rand_chunk(&mut rng)
                }
            })
            .collect();
        let mut pipe = Vec::new();
        for f in &frames {
            write_frame(&mut pipe, f).unwrap();
        }
        let mut cur = std::io::Cursor::new(pipe);
        for (i, f) in frames.iter().enumerate() {
            let got = read_frame(&mut cur)
                .unwrap_or_else(|e| panic!("seed {seed} frame {i}: {e}"));
            assert_eq!(&got, f, "seed {seed} frame {i}");
        }
    }
}

/// The GG control frames added for the data plane (WaitArmed / WaitDone /
/// Retire) roundtrip for arbitrary ids, alongside the original calls.
#[test]
fn prop_rpc_request_roundtrip() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0xC0DE);
        let reqs = [
            Request::Sync {
                worker: rng.next_u32(),
                speed: ripples::rpc::SpeedReport::new(rng.gen_f64() * 0.1),
            },
            Request::Complete { id: rng.next_u64() },
            Request::WaitArmed { id: rng.next_u64() },
            Request::WaitDone { id: rng.next_u64() },
            Request::Retire { worker: rng.next_u32() },
            Request::Stats,
            Request::Shutdown,
        ];
        for req in reqs {
            assert_eq!(
                Request::decode(&req.encode()).unwrap(),
                req,
                "seed {seed}"
            );
        }
        let resp = Response::Assigned {
            id: rng.next_u64(),
            members: (0..rng.gen_range(9)).map(|_| rng.next_u32()).collect(),
            armed: vec![(rng.next_u64(), vec![rng.next_u32()])],
        };
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp, "seed {seed}");
        // the Stats response carries the per-worker speed table
        let n = rng.gen_range(6);
        let resp = Response::Stats(ripples::rpc::StatsReport {
            requests: rng.next_u64(),
            conflicts: rng.next_u64(),
            groups_created: rng.next_u64(),
            buffer_hits: rng.next_u64(),
            speeds: (0..n).map(|_| rng.gen_f64()).collect(),
            drafts: (0..n).map(|_| rng.next_u64()).collect(),
            last_drafted: (0..n).map(|_| rng.next_u64()).collect(),
            deaths: rng.next_u64(),
            groups_aborted: rng.next_u64(),
            rejoins: rng.next_u64(),
        });
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp, "seed {seed}");
    }
}
