//! Property-based tests of the data-plane wire frames, in the style of
//! `prop_gg.rs`: hand-rolled randomized harness (no proptest in the
//! vendored registry), seeds in panic messages for reproducibility.

use ripples::collectives::codec::{
    f16_bits_to_f32, f32_to_f16_bits, q8_params, q8_quantize_one, F16_ABS_ERR, F16_MAX,
    F16_REL_ERR,
};
use ripples::collectives::WireCodec;
use ripples::net::frame::{read_frame, write_frame, Frame};
use ripples::rpc::{Request, Response};
use ripples::util::rng::Pcg32;

const SEEDS: u64 = 60;

fn rand_chunk(rng: &mut Pcg32) -> Frame {
    let count = rng.gen_range(2049);
    let data: Vec<f32> = (0..count)
        .map(|_| {
            // cover exact-bit-pattern extremes, not just uniform draws
            match rng.gen_range(8) {
                0 => 0.0,
                1 => -0.0,
                2 => f32::MIN,
                3 => f32::MAX,
                4 => f32::MIN_POSITIVE,
                _ => rng.gen_f32() * 2e6 - 1e6,
            }
        })
        .collect();
    Frame::Chunk { gid: rng.next_u64(), step: rng.next_u32(), data }
}

/// Every chunk frame survives encode -> decode bit-exactly.
#[test]
fn prop_chunk_roundtrip() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed);
        let frame = rand_chunk(&mut rng);
        let decoded = Frame::decode(&frame.encode())
            .unwrap_or_else(|e| panic!("seed {seed}: decode failed: {e}"));
        // PartialEq on f32 vectors is what we want here: the codec must
        // preserve exact bit patterns (NaN is excluded by construction).
        assert_eq!(decoded, frame, "seed {seed}");
    }
}

/// The encoded size is exactly the header plus 4 bytes per element —
/// nothing hidden, nothing padded (the cost model charges per byte).
#[test]
fn prop_chunk_encoding_size() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed);
        let frame = rand_chunk(&mut rng);
        let Frame::Chunk { ref data, .. } = frame else { unreachable!() };
        assert_eq!(
            frame.encode().len(),
            1 + 8 + 4 + 4 + 4 * data.len(),
            "seed {seed}"
        );
    }
}

/// Any strict prefix of a valid frame must fail to decode (truncation is
/// detected, never silently zero-filled).
#[test]
fn prop_truncation_detected() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed);
        let frame = rand_chunk(&mut rng);
        let buf = frame.encode();
        // a handful of random cut points plus the boundary cases
        let mut cuts = vec![0, 1, buf.len() - 1];
        for _ in 0..8 {
            cuts.push(rng.gen_range(buf.len()));
        }
        for cut in cuts {
            assert!(
                Frame::decode(&buf[..cut]).is_err(),
                "seed {seed}: truncation at {cut}/{} decoded",
                buf.len()
            );
        }
    }
}

/// Appending trailing garbage must fail to decode (frames are
/// length-delimited by the outer transport; slack means corruption).
#[test]
fn prop_trailing_bytes_detected() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed);
        let mut buf = rand_chunk(&mut rng).encode();
        buf.push(rng.next_u32() as u8);
        assert!(Frame::decode(&buf).is_err(), "seed {seed}");
    }
}

/// Streamed frames (length-prefixed over a byte pipe) arrive in order and
/// intact — the mesh's actual on-socket format.
#[test]
fn prop_stream_sequence_roundtrip() {
    for seed in 0..SEEDS / 4 {
        let mut rng = Pcg32::new(seed ^ 0x57EA);
        let frames: Vec<Frame> = (0..rng.gen_range(6) + 1)
            .map(|i| {
                if i == 0 {
                    Frame::Hello { rank: rng.next_u32() }
                } else {
                    rand_chunk(&mut rng)
                }
            })
            .collect();
        let mut pipe = Vec::new();
        for f in &frames {
            write_frame(&mut pipe, f).unwrap();
        }
        let mut cur = std::io::Cursor::new(pipe);
        for (i, f) in frames.iter().enumerate() {
            let got = read_frame(&mut cur)
                .unwrap_or_else(|e| panic!("seed {seed} frame {i}: {e}"));
            assert_eq!(&got, f, "seed {seed} frame {i}");
        }
    }
}

fn rand_coded_chunk(rng: &mut Pcg32) -> Frame {
    let count = rng.gen_range(1025);
    if rng.gen_range(2) == 0 {
        Frame::Chunk16 {
            gid: rng.next_u64(),
            step: rng.next_u32(),
            data: (0..count).map(|_| f32_to_f16_bits(rng.gen_f32() * 2e3 - 1e3)).collect(),
        }
    } else {
        let vals: Vec<f32> = (0..count).map(|_| rng.gen_f32() * 2.0 - 1.0).collect();
        let (lo, scale) = q8_params(&vals);
        Frame::ChunkQ8 {
            gid: rng.next_u64(),
            step: rng.next_u32(),
            lo,
            scale,
            data: vals.iter().map(|&v| q8_quantize_one(v, lo, scale)).collect(),
        }
    }
}

/// Compressed chunk frames survive encode -> decode bit-exactly (the
/// lossy step is the *codec*, not the framing), and truncation of any
/// strict prefix is detected.
#[test]
fn prop_coded_chunk_roundtrip_and_truncation() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0xC0DEC);
        let frame = rand_coded_chunk(&mut rng);
        let buf = frame.encode();
        let decoded = Frame::decode(&buf)
            .unwrap_or_else(|e| panic!("seed {seed}: decode failed: {e}"));
        assert_eq!(decoded, frame, "seed {seed}");
        for _ in 0..6 {
            let cut = rng.gen_range(buf.len());
            assert!(
                Frame::decode(&buf[..cut]).is_err(),
                "seed {seed}: truncation at {cut}/{} decoded",
                buf.len()
            );
        }
    }
}

/// fp16 encode→decode error stays within the documented bound across a
/// proptest-style sweep: normals over 12 orders of magnitude, f32/f16
/// subnormals, saturation boundary, ±inf guards.
#[test]
fn prop_fp16_roundtrip_error_bound() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0xF16);
        for i in 0..64 {
            let v: f32 = match i % 8 {
                0 => f32::from_bits(rng.next_u32() & 0x007f_ffff), // f32 subnormal
                1 => (rng.gen_f32() * 2.0 - 1.0) * 2.0f32.powi(-20), // f16-subnormal range
                2 => (rng.gen_f32() * 2.0 - 1.0) * 65504.0,
                3 => [f32::INFINITY, f32::NEG_INFINITY][rng.gen_range(2)],
                4 => (rng.gen_f32() * 2.0 - 1.0) * 1e9, // overflow range
                _ => (rng.gen_f32() * 2.0 - 1.0) * 10.0f32.powi(rng.gen_range(9) as i32 - 4),
            };
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert!(back.is_finite(), "seed {seed}: {v} decoded non-finite");
            if v.is_infinite() || v.abs() > F16_MAX {
                // ±inf guard / overflow: saturate to ±F16_MAX
                assert_eq!(back, F16_MAX.copysign(v), "seed {seed}: {v} -> {back}");
            } else {
                let err = (back as f64 - v as f64).abs();
                let bound = (v.abs() as f64 * F16_REL_ERR as f64).max(F16_ABS_ERR as f64);
                assert!(
                    err <= bound,
                    "seed {seed}: v={v} back={back} err={err} > bound={bound}"
                );
            }
        }
    }
}

/// q8 encode→decode error stays within the documented per-chunk bound
/// `(hi-lo)/510` (plus f32 rounding slack) across value sweeps.
#[test]
fn prop_q8_roundtrip_error_bound() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0x9_8);
        let n = rng.gen_range(512) + 1;
        let span = 10.0f32.powi(rng.gen_range(9) as i32 - 4);
        let offset = (rng.gen_f32() * 2.0 - 1.0) * span;
        let vals: Vec<f32> =
            (0..n).map(|_| offset + (rng.gen_f32() * 2.0 - 1.0) * span).collect();
        let (lo, scale) = q8_params(&vals);
        let step = scale / 255.0;
        let maxabs = vals.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for &v in &vals {
            let back = lo + q8_quantize_one(v, lo, scale) as f32 * step;
            let err = (back as f64 - v as f64).abs();
            let bound = scale as f64 / 500.0 + maxabs as f64 * 1e-5;
            assert!(err <= bound, "seed {seed}: v={v} back={back} err={err} > {bound}");
        }
    }
}

/// The sharded ring under a lossy codec stays within tolerance of the
/// fp32 oracle: every rank converges to (approximately) the same mean,
/// with worst-case error bounded by the per-hop quantization noise.
#[test]
fn prop_sharded_ring_with_codec_matches_fp32_oracle() {
    use ripples::collectives::pipeline::ring_allreduce_sharded;
    use ripples::collectives::ring::ChannelTransport;
    for seed in 0..SEEDS / 6 {
        let mut rng = Pcg32::new(seed ^ 0x51A6);
        let p = 2 + rng.gen_range(3); // 2..=4 ranks
        let n = 16 + rng.gen_range(101);
        let k = 1 + rng.gen_range(4); // 1..=4 shards
        let bufs: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..n).map(|_| rng.gen_f32() * 2.0 - 1.0).collect())
            .collect();
        let oracle: Vec<f32> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>() / p as f32)
            .collect();
        for (codec, tol) in [(WireCodec::Fp16, 1e-2f32), (WireCodec::Q8, 0.08)] {
            let mut coded = bufs.clone();
            let transports = ChannelTransport::ring_with(p, codec);
            std::thread::scope(|scope| {
                for ((r, buf), mut t) in coded.iter_mut().enumerate().zip(transports) {
                    scope.spawn(move || {
                        ring_allreduce_sharded(r, p, buf, k, &mut t, |_, _| ())
                            .expect("coded ring");
                    });
                }
            });
            for (r, buf) in coded.iter().enumerate() {
                for i in 0..n {
                    let err = (buf[i] - oracle[i]).abs();
                    assert!(
                        err <= tol,
                        "seed {seed} {codec} p={p} k={k} rank={r} idx={i}: \
                         {} vs oracle {} (err {err})",
                        buf[i],
                        oracle[i]
                    );
                }
            }
        }
    }
}

/// The GG control frames added for the data plane (WaitArmed / WaitDone /
/// Retire) roundtrip for arbitrary ids, alongside the original calls.
#[test]
fn prop_rpc_request_roundtrip() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0xC0DE);
        let reqs = [
            Request::Sync {
                worker: rng.next_u32(),
                speed: ripples::rpc::SpeedReport::new(rng.gen_f64() * 0.1),
            },
            Request::Complete { id: rng.next_u64() },
            Request::WaitArmed { id: rng.next_u64() },
            Request::WaitDone { id: rng.next_u64() },
            Request::Retire { worker: rng.next_u32() },
            Request::Stats,
            Request::Shutdown,
        ];
        for req in reqs {
            assert_eq!(
                Request::decode(&req.encode()).unwrap(),
                req,
                "seed {seed}"
            );
        }
        // random node-major sync plans (possibly empty = flat) must survive
        // the wire alongside the members they partition
        let rand_plan = |rng: &mut Pcg32| -> Vec<Vec<u32>> {
            (0..rng.gen_range(4))
                .map(|_| (0..1 + rng.gen_range(3)).map(|_| rng.next_u32()).collect())
                .collect()
        };
        let resp = Response::Assigned {
            id: rng.next_u64(),
            members: (0..rng.gen_range(9)).map(|_| rng.next_u32()).collect(),
            plan: rand_plan(&mut rng),
            armed: vec![(rng.next_u64(), vec![rng.next_u32()], rand_plan(&mut rng))],
        };
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp, "seed {seed}");
        let resp = Response::Armed {
            groups: vec![(rng.next_u64(), vec![rng.next_u32()], rand_plan(&mut rng))],
        };
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp, "seed {seed}");
        // the Stats response carries the per-worker speed table
        let n = rng.gen_range(6);
        let resp = Response::Stats(ripples::rpc::StatsReport {
            requests: rng.next_u64(),
            conflicts: rng.next_u64(),
            groups_created: rng.next_u64(),
            buffer_hits: rng.next_u64(),
            speeds: (0..n).map(|_| rng.gen_f64()).collect(),
            drafts: (0..n).map(|_| rng.next_u64()).collect(),
            last_drafted: (0..n).map(|_| rng.next_u64()).collect(),
            deaths: rng.next_u64(),
            groups_aborted: rng.next_u64(),
            rejoins: rng.next_u64(),
        });
        assert_eq!(Response::decode(&resp.encode()).unwrap(), resp, "seed {seed}");
    }
}

/// The PS key-range partition (`net::ps` pushes/pulls shard `s` of `k`
/// via `shard_bounds`) tiles the model exactly for arbitrary
/// `(model_len, shards)`: contiguous, disjoint, covering, and balanced
/// within one element.
#[test]
fn prop_ps_shard_partition_tiles_the_model() {
    use ripples::collectives::pipeline::shard_bounds;
    for seed in 0..SEEDS * 4 {
        let mut rng = Pcg32::new(seed ^ 0x5A4D);
        let n = 1 + rng.gen_range(4096);
        let k = 1 + rng.gen_range(64); // sometimes k > n: empty shards allowed
        let mut expect_lo = 0usize;
        let (mut smallest, mut largest) = (usize::MAX, 0usize);
        for s in 0..k {
            let (lo, hi) = shard_bounds(n, k, s);
            assert_eq!(lo, expect_lo, "seed {seed}: gap/overlap at shard {s} (n={n} k={k})");
            assert!(hi >= lo, "seed {seed}: inverted shard {s} (n={n} k={k})");
            smallest = smallest.min(hi - lo);
            largest = largest.max(hi - lo);
            expect_lo = hi;
        }
        assert_eq!(expect_lo, n, "seed {seed}: shards do not cover 0..{n} (k={k})");
        assert!(
            largest - smallest <= 1,
            "seed {seed}: unbalanced shards (n={n} k={k}): sizes span {smallest}..{largest}"
        );
    }
}

/// `pairwise_average` is AD-PSGD's atomic averaging step: both sides end
/// bit-identical, and each pair's elementwise f32 sum is preserved
/// *exactly* — the mean is computed once from the sum and halved, and
/// halving then re-doubling a (normal-range) f32 round-trips bit-for-bit.
#[test]
fn prop_pairwise_average_preserves_each_pair_sum() {
    use ripples::net::pairwise_average;
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0xADA5);
        let n = 1 + rng.gen_range(512);
        let mut a: Vec<f32> = (0..n).map(|_| rng.gen_f32() * 2e3 - 1e3).collect();
        let mut b: Vec<f32> = (0..n)
            .map(|i| match rng.gen_range(4) {
                0 => -a[i], // exact cancellation: the sum is a signed zero
                1 => 0.0,
                _ => rng.gen_f32() * 2e3 - 1e3,
            })
            .collect();
        let sums: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        pairwise_average(&mut a, &mut b);
        for i in 0..n {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "seed {seed}: sides diverge at {i}");
            assert_eq!(
                (a[i] + b[i]).to_bits(),
                sums[i].to_bits(),
                "seed {seed}: pair sum drifted at {i}: {} -> {}",
                sums[i],
                a[i] + b[i]
            );
        }
    }
}

/// The staged step pipeline's bounded SPSC handoff under seeded
/// interleavings (producer and consumer jitter independently per seed):
/// items arrive as the exact ordered prefix of what was pushed (no
/// loss, duplication, or reordering), occupancy never exceeds the
/// configured capacity, and a poisoned queue still drains every item
/// enqueued before the poison before reporting the fault.
#[test]
fn pipeline_bounded_queue_order_capacity_poison_drain() {
    use ripples::step::{Bounded, QueueEnd};
    use std::time::Duration;
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0x0B5C);
        let cap = 1 + rng.gen_range(8);
        let total = 8 + rng.gen_range(120);
        let poison = rng.gen_range(2) == 0;
        // poison mid-stream: the producer stops after `sent` items
        let sent = if poison { rng.gen_range(total) } else { total };
        let q = Bounded::<u64>::new(cap);
        let (prod_seed, cons_seed) = (seed ^ 0x9A0D, seed ^ 0x50B);
        let qp = std::sync::Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            let mut prng = Pcg32::new(prod_seed);
            for i in 0..sent as u64 {
                if prng.gen_range(4) == 0 {
                    std::thread::yield_now();
                }
                if prng.gen_range(16) == 0 {
                    std::thread::sleep(Duration::from_micros(prng.gen_range(50) as u64));
                }
                assert!(qp.push(i).is_ok(), "queue ended under the producer");
            }
            if poison {
                qp.poison();
            } else {
                qp.close();
            }
        });
        let mut crng = Pcg32::new(cons_seed);
        let mut got = Vec::new();
        let end = loop {
            if crng.gen_range(4) == 0 {
                std::thread::yield_now();
            }
            if crng.gen_range(16) == 0 {
                std::thread::sleep(Duration::from_micros(crng.gen_range(50) as u64));
            }
            match q.pop() {
                Ok(v) => got.push(v),
                Err(e) => break e,
            }
        };
        producer.join().unwrap();
        let expect: Vec<u64> = (0..sent as u64).collect();
        assert_eq!(got, expect, "seed {seed} cap={cap} poison={poison}");
        assert_eq!(
            end,
            if poison { QueueEnd::Poisoned } else { QueueEnd::Closed },
            "seed {seed}"
        );
        assert!(
            q.max_occupancy() <= cap,
            "seed {seed}: occupancy {} exceeded cap {cap}",
            q.max_occupancy()
        );
    }
}

/// Disjoint mutable borrows of two models in the cluster.
fn pair_mut<T>(v: &mut [Vec<T>], i: usize, j: usize) -> (&mut [T], &mut [T]) {
    assert!(i != j);
    let (lo, hi) = (i.min(j), i.max(j));
    let (head, tail) = v.split_at_mut(hi);
    if i < j {
        (&mut head[lo], &mut tail[0])
    } else {
        (&mut tail[0], &mut head[lo])
    }
}

/// A random gossip schedule of pairwise averages conserves the
/// cluster-wide weight sum: every exchange moves mass between two models
/// but never creates or destroys it. Each op perturbs the *exact* sum by
/// at most the f32 rounding of one pair sum per coordinate, so the total
/// drift is bounded far below the signal.
#[test]
fn prop_random_gossip_conserves_global_weight_sum() {
    use ripples::net::pairwise_average;
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0x6055);
        let workers = 2 + rng.gen_range(7);
        let n = 1 + rng.gen_range(64);
        let mut models: Vec<Vec<f32>> = (0..workers)
            .map(|_| (0..n).map(|_| rng.gen_f32() * 2e3 - 1e3).collect())
            .collect();
        let global = |ms: &[Vec<f32>]| -> f64 {
            ms.iter().flatten().map(|&v| v as f64).sum()
        };
        let before = global(&models);
        let rounds = 64;
        for _ in 0..rounds {
            let w = rng.gen_range(workers);
            let p = (w + 1 + rng.gen_range(workers - 1)) % workers;
            let (a, b) = pair_mut(&mut models, w, p);
            pairwise_average(a, b);
        }
        // values stay in the initial ±1e3 hull, so |x + y| <= 2e3 and one
        // pair-sum rounding is at most ulp(2e3)/2 ~ 6.1e-5 per coordinate
        let bound = rounds as f64 * n as f64 * 2.5e-4;
        let drift = (global(&models) - before).abs();
        assert!(drift <= bound, "seed {seed}: weight sum drifted {drift} > {bound}");
    }
}
