//! End-to-end tests over the PJRT artifacts: the three-layer composition.
//!
//! These require `make artifacts` to have run; when the artifacts
//! directory is missing the tests skip with a notice (the Makefile's
//! `test` target always builds artifacts first, so CI exercises them).

use std::path::PathBuf;
use std::time::Duration;

use ripples::cluster::HeterogeneityProfile;
use ripples::collectives::OverlapConfig;
use ripples::runtime::threaded::{
    run_threaded, synth_batch, synth_tokens, EngineClient, ThreadSched, ThreadedConfig,
    Workload,
};
use ripples::runtime::PjrtEngine;
use ripples::util::rng::Pcg32;

fn artifacts() -> Option<PathBuf> {
    let dir = ripples::runtime::artifacts_dir();
    if dir.join("mlp_train_step.meta.json").is_file() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn artifact_listing_and_compile() {
    let Some(dir) = artifacts() else { return };
    let mut engine = PjrtEngine::new(&dir).unwrap();
    let names = engine.available();
    for required in [
        "mlp_train_step",
        "mlp_train_step_pallas",
        "mlp_eval",
        "mlp_init",
        "tlm_train_step",
        "tlm_init",
        "preduce_mlp_g2",
        "preduce_mlp_g3",
        "preduce_tlm_g3",
    ] {
        assert!(names.iter().any(|n| n == required), "missing artifact {required}");
    }
    let c = engine.load("mlp_train_step").unwrap();
    assert_eq!(c.meta.param_count, 22026);
}

#[test]
fn preduce_artifact_is_group_mean() {
    let Some(dir) = artifacts() else { return };
    let mut engine = PjrtEngine::new(&dir).unwrap();
    let n = engine.load("preduce_mlp_g3").unwrap().meta.param_count;
    let mut rng = Pcg32::new(5);
    let a: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
    let c: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
    let mut stacked = a.clone();
    stacked.extend_from_slice(&b);
    stacked.extend_from_slice(&c);
    let mean = engine.preduce("preduce_mlp_g3", &stacked).unwrap();
    for i in (0..n).step_by(97) {
        let expect = (a[i] + b[i] + c[i]) / 3.0;
        assert!((mean[i] - expect).abs() < 1e-5, "idx {i}");
    }
}

#[test]
fn mlp_artifact_trains_and_pallas_variant_agrees() {
    let Some(dir) = artifacts() else { return };
    let mut engine = PjrtEngine::new(&dir).unwrap();
    let flat0 = engine.init_model("mlp_init", 0).unwrap();
    assert_eq!(flat0, engine.init_model("mlp_init", 0).unwrap(), "init not deterministic");
    let mut rng = Pcg32::new(11);
    let (x, y) = synth_batch(&mut rng, 128, 32, 10);
    // jnp path: loss decreases over repeated steps on a fixed batch
    let mut flat = flat0.clone();
    let (_, first_loss) = engine
        .mlp_train_step("mlp_train_step", &flat, &x, &y, 0.05)
        .unwrap();
    for _ in 0..10 {
        let (nf, _) = engine
            .mlp_train_step("mlp_train_step", &flat, &x, &y, 0.05)
            .unwrap();
        flat = nf;
    }
    let (_, last_loss) = engine
        .mlp_train_step("mlp_train_step", &flat, &x, &y, 0.05)
        .unwrap();
    assert!(last_loss < first_loss, "loss {first_loss} -> {last_loss}");
    // the Pallas variant computes the same math (Layer-1 == Layer-2 check
    // across the AOT boundary; the python suite already checks pre-AOT)
    let (flat_j, loss_j) = engine
        .mlp_train_step("mlp_train_step", &flat0, &x, &y, 0.05)
        .unwrap();
    let (flat_p, loss_p) = engine
        .mlp_train_step("mlp_train_step_pallas", &flat0, &x, &y, 0.05)
        .unwrap();
    assert!((loss_j - loss_p).abs() < 1e-3, "losses {loss_j} vs {loss_p}");
    let mut worst = 0.0f32;
    for i in 0..flat_j.len() {
        worst = worst.max((flat_j[i] - flat_p[i]).abs());
    }
    assert!(worst < 1e-2, "param drift {worst}");
}

#[test]
fn tlm_artifact_learns_successor_rule() {
    let Some(dir) = artifacts() else { return };
    let mut engine = PjrtEngine::new(&dir).unwrap();
    let mut flat = engine.init_model("tlm_init", 0).unwrap();
    let mut rng = Pcg32::new(3);
    let tokens = synth_tokens(&mut rng, 8, 64, 256);
    let (_, first) = engine.tlm_train_step("tlm_train_step", &flat, &tokens, 0.3).unwrap();
    assert!((first - (256f32).ln()).abs() < 1.0, "init loss {first} far from ln(V)");
    for _ in 0..8 {
        let (nf, _) = engine.tlm_train_step("tlm_train_step", &flat, &tokens, 0.3).unwrap();
        flat = nf;
    }
    let (_, last) = engine.tlm_train_step("tlm_train_step", &flat, &tokens, 0.3).unwrap();
    assert!(last < first - 0.5, "LM loss {first} -> {last}");
}

#[test]
fn threaded_smart_gg_full_stack() {
    let Some(dir) = artifacts() else { return };
    let (engine, _h) = EngineClient::spawn(dir).unwrap();
    let cfg = ThreadedConfig {
        n_nodes: 2,
        workers_per_node: 2,
        iters: 8,
        group_size: 2,
        sched: ThreadSched::SmartGg,
        lr: 0.05,
        seed: 1,
        hetero: HeterogeneityProfile::default(),
        workload: Workload::Mlp { batch: 128, in_dim: 32, classes: 10 },
        step_artifact: "mlp_train_step".into(),
        init_artifact: "mlp_init".into(),
        preduce_prefix: "preduce_mlp_g".into(),
        compute_floor: Duration::ZERO,
        overlap: OverlapConfig::serial(),
        prefetch: 0,
        load_floor: Duration::ZERO,
    };
    let report = run_threaded(cfg, engine).unwrap();
    assert_eq!(report.per_worker_iters, vec![8, 8, 8, 8]);
    assert!(report.preduce_count > 0, "no P-Reduces happened");
    // loss trend: mean of first iteration vs last
    let mean_at = |it: u64| -> f32 {
        let v: Vec<f32> = report
            .losses
            .iter()
            .filter(|&&(_, i, _)| i == it)
            .map(|&(_, _, l)| l)
            .collect();
        v.iter().sum::<f32>() / v.len() as f32
    };
    assert!(mean_at(7) < mean_at(0), "{} -> {}", mean_at(0), mean_at(7));
}

#[test]
fn threaded_static_schedule_full_stack() {
    let Some(dir) = artifacts() else { return };
    let (engine, _h) = EngineClient::spawn(dir).unwrap();
    let cfg = ThreadedConfig {
        n_nodes: 2,
        workers_per_node: 2,
        iters: 8,
        group_size: 2,
        sched: ThreadSched::Static,
        lr: 0.05,
        seed: 2,
        hetero: HeterogeneityProfile {
            slow_worker: Some((1, 2.0)),
            ..HeterogeneityProfile::default()
        },
        workload: Workload::Mlp { batch: 128, in_dim: 32, classes: 10 },
        step_artifact: "mlp_train_step".into(),
        init_artifact: "mlp_init".into(),
        preduce_prefix: "preduce_mlp_g".into(),
        compute_floor: Duration::from_millis(1),
        overlap: OverlapConfig::serial(),
        prefetch: 0,
        load_floor: Duration::ZERO,
    };
    let report = run_threaded(cfg, engine).unwrap();
    assert_eq!(report.per_worker_iters, vec![8; 4]);
    assert!(report.preduce_count > 0);
    // after the final intra-node phase, node peers should share weights
    // only if the last schedule step synced them; at minimum, replicas
    // must not have diverged wildly (consensus contraction)
    let spread: f32 = {
        let n = report.final_models[0].len();
        let mut worst = 0.0f32;
        for i in (0..n).step_by(53) {
            let vals: Vec<f32> = report.final_models.iter().map(|m| m[i]).collect();
            let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            worst = worst.max(hi - lo);
        }
        worst
    };
    assert!(spread < 1.0, "replicas diverged: spread {spread}");
}

#[test]
fn threaded_smart_gg_seed_stress() {
    // Deadlock regression: the GD fallback used to draft *busy* workers,
    // creating circular waits between a worker's front group and a
    // late-armed group (hung at scale). Sweep seeds and shapes; any
    // deadlock hangs the test harness and fails CI by timeout.
    let Some(dir) = artifacts() else { return };
    let (engine, _h) = EngineClient::spawn(dir).unwrap();
    for seed in 0..6u64 {
        let (nodes, wpn) = [(2, 2), (2, 4), (4, 2)][seed as usize % 3];
        let cfg = ThreadedConfig {
            n_nodes: nodes,
            workers_per_node: wpn,
            iters: 6,
            group_size: 3.min(nodes * wpn - 1),
            sched: ThreadSched::SmartGg,
            lr: 0.05,
            seed,
            hetero: if seed % 2 == 0 {
                HeterogeneityProfile::default()
            } else {
                HeterogeneityProfile {
                    slow_worker: Some((1, 3.0)),
                    ..HeterogeneityProfile::default()
                }
            },
            workload: Workload::Mlp { batch: 128, in_dim: 32, classes: 10 },
            step_artifact: "mlp_train_step".into(),
            init_artifact: "mlp_init".into(),
            preduce_prefix: "preduce_mlp_g".into(),
            compute_floor: Duration::ZERO,
            overlap: OverlapConfig::serial(),
            prefetch: 0,
            load_floor: Duration::ZERO,
        };
        let report = run_threaded(cfg, engine.clone()).unwrap();
        assert!(
            report.per_worker_iters.iter().all(|&i| i == 6),
            "seed {seed}: incomplete iterations {:?}",
            report.per_worker_iters
        );
    }
}

#[test]
fn threaded_overlap_hides_straggler_wait() {
    // In-process overlap acceptance: with a 3x straggler, fast workers
    // waiting at their sync points take bounded stale steps instead of
    // parking — total exposed sync wait must drop vs the serial run at
    // an equivalent final model (consensus preserved).
    let Some(dir) = artifacts() else { return };
    let (engine, _h) = EngineClient::spawn(dir).unwrap();
    let base = ThreadedConfig {
        n_nodes: 2,
        workers_per_node: 2,
        iters: 10,
        group_size: 2,
        sched: ThreadSched::SmartGg,
        lr: 0.05,
        seed: 9,
        hetero: HeterogeneityProfile {
            slow_worker: Some((1, 3.0)),
            ..HeterogeneityProfile::default()
        },
        workload: Workload::Mlp { batch: 128, in_dim: 32, classes: 10 },
        step_artifact: "mlp_train_step".into(),
        init_artifact: "mlp_init".into(),
        preduce_prefix: "preduce_mlp_g".into(),
        compute_floor: Duration::from_millis(4),
        overlap: OverlapConfig::serial(),
        prefetch: 0,
        load_floor: Duration::ZERO,
    };
    let serial = run_threaded(base.clone(), engine.clone()).unwrap();
    let mut over_cfg = base;
    over_cfg.overlap = OverlapConfig { shards: 4, max_staleness: 4 };
    let overlapped = run_threaded(over_cfg, engine).unwrap();

    assert_eq!(overlapped.per_worker_iters, vec![10; 4]);
    assert!(overlapped.preduce_count > 0);
    assert_eq!(serial.stale_steps, vec![0; 4], "serial mode must not stale-step");
    let stale_total: u64 = overlapped.stale_steps.iter().sum();
    assert!(stale_total > 0, "overlap never hid any wait: {:?}", overlapped.stale_steps);
    let wait = |r: &ripples::runtime::threaded::ThreadedReport| -> f64 {
        r.sync_wait.iter().map(|d| d.as_secs_f64()).sum()
    };
    assert!(
        wait(&overlapped) < wait(&serial),
        "exposed sync wait did not drop: overlap {:.4}s vs serial {:.4}s",
        wait(&overlapped),
        wait(&serial)
    );
    // replicas still contract toward consensus under stale averaging
    let spread = |models: &[Vec<f32>]| -> f32 {
        let n = models[0].len();
        let mut worst = 0.0f32;
        for i in (0..n).step_by(53) {
            let vals: Vec<f32> = models.iter().map(|m| m[i]).collect();
            let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            worst = worst.max(hi - lo);
        }
        worst
    };
    assert!(
        spread(&overlapped.final_models) < 1.0,
        "replicas diverged under overlap: {}",
        spread(&overlapped.final_models)
    );
}

#[test]
fn threaded_prefetch_hides_load_floor() {
    // Staged pipeline acceptance on the threaded runtime: with compute
    // dominating a nontrivial batch-load floor, the prefetching loader
    // hides nearly all load time (only priming stays exposed), while
    // the lockstep loop pays the floor on every iteration.
    let Some(dir) = artifacts() else { return };
    let (engine, _h) = EngineClient::spawn(dir).unwrap();
    let base = ThreadedConfig {
        n_nodes: 1,
        workers_per_node: 2,
        iters: 8,
        group_size: 2,
        sched: ThreadSched::SmartGg,
        lr: 0.05,
        seed: 13,
        hetero: HeterogeneityProfile::default(),
        workload: Workload::Mlp { batch: 128, in_dim: 32, classes: 10 },
        step_artifact: "mlp_train_step".into(),
        init_artifact: "mlp_init".into(),
        preduce_prefix: "preduce_mlp_g".into(),
        compute_floor: Duration::from_millis(15),
        overlap: OverlapConfig::serial(),
        prefetch: 0,
        load_floor: Duration::from_millis(5),
    };
    let lockstep = run_threaded(base.clone(), engine.clone()).unwrap();
    let mut staged_cfg = base;
    staged_cfg.prefetch = 4;
    let staged = run_threaded(staged_cfg, engine).unwrap();
    assert_eq!(lockstep.per_worker_iters, vec![8; 2]);
    assert_eq!(staged.per_worker_iters, vec![8; 2]);
    let wait = |r: &ripples::runtime::threaded::ThreadedReport| -> f64 {
        r.load_wait.iter().map(|d| d.as_secs_f64()).sum()
    };
    // lockstep exposes the full floor every step: 2 workers x 8 x 5ms
    assert!(wait(&lockstep) >= 0.060, "lockstep load wait {:.4}s", wait(&lockstep));
    assert!(
        wait(&staged) < 0.5 * wait(&lockstep),
        "prefetch did not hide the load floor: staged {:.4}s vs lockstep {:.4}s",
        wait(&staged),
        wait(&lockstep)
    );
    // stage meters: no loader thread exists in lockstep mode, and the
    // staged loader must have hit backpressure (compute is slower)
    assert_eq!(lockstep.compute_wait, vec![Duration::ZERO; 2]);
    assert!(
        staged.compute_wait.iter().any(|d| *d > Duration::ZERO),
        "staged loader never blocked on backpressure: {:?}",
        staged.compute_wait
    );
}

#[test]
fn weighted_preduce_artifact() {
    let Some(dir) = artifacts() else { return };
    let mut engine = PjrtEngine::new(&dir).unwrap();
    let c = engine.load("preduce_mlp_g4_weighted").unwrap();
    let n = c.meta.param_count;
    use ripples::runtime::engine::Value;
    let mut stacked = Vec::with_capacity(4 * n);
    for k in 0..4 {
        stacked.extend(std::iter::repeat(k as f32).take(n));
    }
    let weights = [0.4f32, 0.3, 0.2, 0.1];
    let out = c
        .call(&[Value::F32(&stacked), Value::F32(&weights)])
        .unwrap();
    let expect = 0.0 * 0.4 + 1.0 * 0.3 + 2.0 * 0.2 + 3.0 * 0.1;
    assert!((out[0][0] - expect).abs() < 1e-5, "{} vs {expect}", out[0][0]);
    assert!((out[0][n - 1] - expect).abs() < 1e-5);
}
