//! Model-checker integration suite: committed counterexample fixtures,
//! the shared aborted-set cap, and the `results/CHECK_gg.json` artifact
//! shape.
//!
//! The fixtures under `rust/tests/fixtures/check/` are minimized
//! counterexamples produced by `ripples check --mutation <name>`: each
//! is a schedule that drives a *deliberately re-broken* model into an
//! invariant violation. Here every fixture is replayed twice:
//!
//! 1. against the mutated model — it must still reach the violation
//!    (the committed trace stays a real counterexample);
//! 2. against the real `GroupGenerator` and `ShardedGg` — which do not
//!    contain the mutation and therefore must sail through with all
//!    coordination invariants intact, both backends state-identical.

use std::fs;
use std::path::{Path, PathBuf};

use ripples::check::explore::replay_violates;
use ripples::check::{
    membership_deterministic, mutation_cfg, random_walk_conformance,
    replay_against_real, EngineSemantics, Model, ModelCfg, Mutation, Op, Scenario,
};
use ripples::gg::{GgConfig, GroupGenerator, ShardedGg, ABORTED_SET_CAP};
use ripples::util::rng::Pcg32;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/check")
}

/// Parse one committed fixture: `mutation <name>` line, `cfg k=v ...`
/// line, then one op per line (`#` comments skipped).
fn parse_fixture(text: &str) -> (ModelCfg, Mutation, Vec<Op>) {
    let mut mutation = None;
    let mut cfg = None;
    let mut ops = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(name) = line.strip_prefix("mutation ") {
            mutation = Some(Mutation::parse(name.trim()).expect("known mutation"));
        } else if let Some(kvs) = line.strip_prefix("cfg ") {
            cfg = Some(parse_cfg(kvs));
        } else {
            ops.push(Op::parse(line).unwrap_or_else(|| panic!("bad op line: {line}")));
        }
    }
    (cfg.expect("cfg line"), mutation.expect("mutation line"), ops)
}

fn parse_cfg(kvs: &str) -> ModelCfg {
    let mut cfg = ModelCfg {
        n: 0,
        group_size: 0,
        use_group_buffer: false,
        use_global_division: false,
        rendezvous: false,
        engine: EngineSemantics::Sim,
        aborted_cap: 0,
        syncs_per_worker: 0,
        max_deaths: 0,
        max_rejoins: 0,
        max_aborts: 0,
        max_retires: 0,
    };
    for kv in kvs.split_whitespace() {
        let (k, v) = kv.split_once('=').unwrap_or_else(|| panic!("bad cfg pair: {kv}"));
        let num = || v.parse::<usize>().unwrap_or_else(|_| panic!("bad value: {kv}"));
        match k {
            "n" => cfg.n = num(),
            "gs" => cfg.group_size = num(),
            "gb" => cfg.use_group_buffer = num() != 0,
            "gd" => cfg.use_global_division = num() != 0,
            "rnd" => cfg.rendezvous = num() != 0,
            "eng" => {
                cfg.engine = match v {
                    "sim" => EngineSemantics::Sim,
                    "rdv" => EngineSemantics::Rendezvous,
                    other => panic!("bad engine: {other}"),
                }
            }
            "cap" => cfg.aborted_cap = num(),
            "syncs" => cfg.syncs_per_worker = num(),
            "deaths" => cfg.max_deaths = num(),
            "rejoins" => cfg.max_rejoins = num(),
            "aborts" => cfg.max_aborts = num(),
            "retires" => cfg.max_retires = num(),
            other => panic!("unknown cfg key: {other}"),
        }
    }
    cfg
}

fn load_fixture(name: &str) -> (ModelCfg, Mutation, Vec<Op>) {
    let path = fixture_dir().join(name);
    let text = fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    parse_fixture(&text)
}

/// Shared body: the trace must violate on the mutated model and replay
/// cleanly (both backends identical, all invariants green) on the real,
/// unmutated coordinator. Returns one final replay for fixture-specific
/// asserts.
fn check_fixture(name: &str) -> ripples::check::RealReplay {
    let (cfg, mutation, ops) = load_fixture(name);
    assert_ne!(mutation, Mutation::None, "{name}: fixture must name a mutation");
    // The committed cfg is the one `--mutation` self-tests explore with;
    // keep them in lockstep so the fixture cannot silently drift.
    let expect = mutation_cfg(mutation, 3);
    assert_eq!(
        format!("{cfg:?}"),
        format!("{expect:?}"),
        "{name}: fixture cfg drifted from mutation_cfg"
    );
    assert!(
        replay_violates(&Model::new(cfg.clone(), mutation), &ops),
        "{name}: committed trace no longer violates the mutated model"
    );
    let mut last = None;
    for seed in [3, 17, 91] {
        let replay = replay_against_real(&cfg, seed, &ops)
            .unwrap_or_else(|e| panic!("{name} (seed {seed}): real replay failed: {e}"));
        assert_eq!(replay.snapshots.len(), ops.len());
        last = Some(replay);
    }
    last.expect("at least one seed")
}

#[test]
fn fixture_skip_arm_sweep_replays() {
    let replay = check_fixture("skip_arm_sweep.trace");
    // The mutation loses the wakeup; the real coordinator must have
    // swept g2 from pending to armed when g1 completed.
    assert!(replay.oracle.is_armed(2), "real GG lost the wakeup");
    assert_eq!(replay.oracle.pending_len(), 0);
}

#[test]
fn fixture_double_grant_replays() {
    let replay = check_fixture("double_grant.trace");
    // The real coordinator must refuse the second grant: g2 pends.
    assert!(!replay.oracle.is_armed(2));
    assert_eq!(replay.oracle.pending_len(), 1);
}

#[test]
fn fixture_complete_keeps_locks_replays() {
    let replay = check_fixture("complete_keeps_locks.trace");
    for w in 0..3 {
        assert!(!replay.oracle.is_locked_worker(w), "rank {w} lock leaked");
        assert!(!replay.sharded.is_locked_worker(w), "rank {w} lock leaked (sharded)");
    }
}

#[test]
fn fixture_abort_skips_gb_purge_replays() {
    let replay = check_fixture("abort_skips_gb_purge.trace");
    assert!(replay.oracle.was_aborted(1));
    for w in 0..3 {
        assert!(
            replay.oracle.gb_snapshot(w).is_empty(),
            "rank {w} GB still holds the aborted group"
        );
    }
}

#[test]
fn fixture_death_keeps_locks_replays() {
    let replay = check_fixture("death_keeps_locks.trace");
    assert!(replay.oracle.is_dead(2));
    assert!(replay.oracle.live_group_ids().is_empty(), "death purge incomplete");
    for w in 0..3 {
        assert!(!replay.oracle.is_locked_worker(w), "rank {w} lock survived the death");
    }
}

#[test]
fn fixture_draft_busy_replays() {
    let replay = check_fixture("draft_busy.trace");
    // The idle-draft rule the mutation broke: under rendezvous, every
    // armed group sits at the *front* of each member's Group Buffer —
    // no member is stuck behind some other pending group.
    let last = replay.snapshots.last().expect("snapshots");
    for (id, members, armed) in &last.live {
        if !armed {
            continue;
        }
        for &m in members {
            assert_eq!(
                last.gbs[m].first(),
                Some(id),
                "armed g{id} drafted busy rank {m} (GB {:?})",
                last.gbs[m]
            );
        }
    }
}

#[test]
fn fixture_skip_aborted_prune_replays() {
    let replay = check_fixture("skip_aborted_prune.trace");
    for id in 1..=3 {
        assert!(replay.oracle.was_aborted(id));
        assert!(replay.sharded.was_aborted(id));
    }
}

/// The one shared cap ([`ABORTED_SET_CAP`]) bounds the aborted-id memory
/// of *both* backends to the same recent-id window. The sharded backend
/// prunes per shard, so at the window boundary it may lag the oracle by
/// up to `GROUP_SHARDS` ids — but never disagrees well inside or well
/// outside the window, and never retains less than the oracle.
#[test]
fn aborted_cap_agrees_across_backends() {
    const OVERSHOOT: u64 = 96;
    let total = ABORTED_SET_CAP as u64 + OVERSHOOT;
    let gcfg = GgConfig::random(2, 2, 2);
    let mut oracle = GroupGenerator::new(gcfg.clone());
    let mut rng = Pcg32::new(11);
    let sharded = ShardedGg::new(gcfg, 11);
    for i in 0..total {
        let (id, _) = oracle.request(0, &mut rng);
        let id = id.unwrap_or_else(|| panic!("iter {i}: oracle drafted no group"));
        oracle.abort_group(id);
        let (id2, _) = sharded.request(0);
        let id2 = id2.unwrap_or_else(|| panic!("iter {i}: sharded drafted no group"));
        assert_eq!(id, id2, "iter {i}: backends allocated different group ids");
        sharded.abort_group(id2);
    }
    // Ids ran 1..=total; both backends keep exactly the most recent
    // ABORTED_SET_CAP ids (the oracle), modulo per-shard lag of at most
    // 16 ids on the sharded side.
    let min_keep = total + 1 - ABORTED_SET_CAP as u64; // oracle's window start
    let skew = 16;
    assert!(min_keep > skew, "overshoot too small to observe pruning");
    for id in 1..=total {
        let o = oracle.was_aborted(id);
        let s = sharded.was_aborted(id);
        if id >= min_keep {
            assert!(o && s, "id {id} inside the window was pruned (oracle={o} sharded={s})");
        } else if id < min_keep - skew {
            assert!(!o && !s, "id {id} outside the window survived (oracle={o} sharded={s})");
        } else {
            // Boundary: the oracle has pruned; the sharded backend may
            // lag by < GROUP_SHARDS ids but never retains *less*.
            assert!(!o, "oracle kept id {id} beyond its window");
        }
    }
    assert!(!oracle.was_aborted(1) && !sharded.was_aborted(1));
    assert!(oracle.was_aborted(total) && sharded.was_aborted(total));
}

/// Deep random-walk conformance across every bounded scenario — the
/// acceptance path: model traces replay state-identically through the
/// oracle, the sharded backend, and the RPC seam.
#[test]
fn scenario_walks_replay_across_backends() {
    for s in Scenario::ALL {
        let cfg = ripples::check::scenario_cfg(s, 3);
        assert!(membership_deterministic(&cfg), "{}: bad regime", s.name());
        for seed in 0..15 {
            random_walk_conformance(&cfg, seed, 35)
                .unwrap_or_else(|e| panic!("{} seed {seed}: {e}", s.name()));
        }
    }
}

/// Shape of the committed `results/CHECK_gg.json` artifact. Skips (with
/// a notice) when the artifact is absent — `make clean` removes
/// `results/` and `make modelcheck` regenerates it.
#[test]
fn check_artifact_shape() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("results/CHECK_gg.json");
    let Ok(text) = fs::read_to_string(&path) else {
        eprintln!(
            "NOTICE: {} missing — run `make modelcheck` to generate it; skipping",
            path.display()
        );
        return;
    };
    let parsed = ripples::util::json::parse(&text).expect("CHECK_gg.json: invalid JSON");
    assert_eq!(
        parsed.get("id").and_then(|v| v.as_str()),
        Some("gg_modelcheck"),
        "artifact id"
    );
    assert!(parsed.get("placeholder").and_then(|v| v.as_bool()).is_some());
    assert!(parsed.get("ranks").and_then(|v| v.as_usize()).unwrap_or(0) >= 2);
    assert!(parsed.get("depth").and_then(|v| v.as_usize()).unwrap_or(0) >= 1);
    let scenarios =
        parsed.get("scenarios").and_then(|v| v.as_arr()).expect("scenarios array");
    assert_eq!(scenarios.len(), Scenario::ALL.len(), "one entry per scenario");
    for s in scenarios {
        let name = s.get("scenario").and_then(|v| v.as_str()).expect("scenario name");
        assert!(Scenario::parse(name).is_some(), "unknown scenario {name}");
        assert_eq!(
            s.get("violations").and_then(|v| v.as_usize()),
            Some(0),
            "{name}: committed artifact must be violation-free"
        );
        assert!(s.get("states_explored").is_some());
        assert!(s.get("sleep_set_pruned").is_some());
        assert!(s.get("quiescent_states").is_some());
    }
}
