//! End-to-end tests of the baseline data planes: AD-PSGD and the
//! Parameter Server running as real worker *processes* over the same TCP
//! mesh, launcher, and wire codecs as the Ripples collectives
//! (`--algo adpsgd|ps`; DESIGN.md §Baselines).
//!
//! The calibrated four-way speedup *table* lives in the simulator
//! (`fig paper`, pinned by `bench::figures` tests and the committed
//! `BENCH_paper.json`); what these tests pin is the real-socket
//! structure behind it: both baselines train end to end, the PS barrier
//! gates every worker down to the straggler, AD-PSGD cannot steer its
//! random pairwise syncs away from the straggler, and Ripples sustains
//! more cluster work than the barrier baseline in the same wall-clock
//! window.

use std::path::PathBuf;
use std::time::Duration;

use ripples::config::AlgoKind;
use ripples::net::{launch_local, LaunchConfig, LaunchReport};

fn bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_ripples"))
}

/// Hard test timeout (same rationale as `e2e_net`): a protocol
/// regression must fail the test, not hang CI.
fn with_timeout<T, F>(secs: u64, what: &str, f: F) -> T
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(Duration::from_secs(secs))
        .unwrap_or_else(|_| panic!("{what}: hung past the {secs}s test timeout"))
}

fn base(algo: AlgoKind) -> LaunchConfig {
    LaunchConfig {
        bin: bin(),
        workers: 4,
        algo,
        secs: 3.0,
        compute_floor_ms: 8,
        seed: 42,
        ..LaunchConfig::default()
    }
}

fn total_iters(r: &LaunchReport) -> u64 {
    r.workers.iter().map(|w| w.iters).sum()
}

/// A 4-process AD-PSGD cluster (actives 0/2, passives 1/3) trains end to
/// end: every rank's loss drops by the same tolerance the Ripples e2e
/// uses, every rank both ships and meters model bytes, actives complete
/// exchanges and passives serve them.
#[test]
fn four_process_adpsgd_cluster_converges() {
    let report = with_timeout(120, "adpsgd cluster run", || {
        launch_local(&base(AlgoKind::AdPsgd)).expect("adpsgd cluster run")
    });
    assert_eq!(report.workers.len(), 4);
    for w in &report.workers {
        assert!(w.iters > 0, "worker {} never trained: {w:?}", w.rank);
        // actives count exchanges, passives count serves — with two
        // actives pushing every iteration, both must be nonzero
        assert!(w.preduces > 0, "worker {} never synchronized: {w:?}", w.rank);
        assert!(w.bytes_tx > 0, "worker {} metered no tx bytes: {w:?}", w.rank);
        assert!(w.bytes_rx > 0, "worker {} metered no rx bytes: {w:?}", w.rank);
        assert!(
            w.loss_last < w.loss_first * 0.85,
            "worker {} loss did not decrease: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
    }
}

/// A 4-process Parameter Server cluster (server hosted by the launcher,
/// 3 key-range shards) trains end to end. The BSP rounds are atomic —
/// a worker only leaves between rounds, and the first leaver ends the
/// server loop for everyone — so every worker reports the same number
/// of completed rounds (within one).
#[test]
fn four_process_ps_cluster_converges() {
    let cfg = LaunchConfig { ps_shards: 3, ..base(AlgoKind::ParameterServer) };
    let report = with_timeout(120, "ps cluster run", move || {
        launch_local(&cfg).expect("ps cluster run")
    });
    assert_eq!(report.workers.len(), 4);
    for w in &report.workers {
        assert!(w.preduces > 0, "worker {} completed no PS rounds: {w:?}", w.rank);
        assert!(w.bytes_tx > 0, "worker {} metered no tx bytes: {w:?}", w.rank);
        assert!(w.bytes_rx > 0, "worker {} metered no rx bytes: {w:?}", w.rank);
        assert!(
            w.loss_last < w.loss_first * 0.85,
            "worker {} loss did not decrease: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
    }
    let rounds: Vec<u64> = report.workers.iter().map(|w| w.preduces).collect();
    let (min, max) = (
        rounds.iter().copied().min().unwrap(),
        rounds.iter().copied().max().unwrap(),
    );
    assert!(max - min <= 1, "BSP rounds diverged across workers: {rounds:?}");
}

/// The heterogeneous acceptance scenario: the same 4-process cluster with
/// worker 1 slowed 3x, run under all three algorithms for the same
/// wall-clock window (the paper's Fig. 1 / Fig. 19 setting on real
/// sockets). Ripples must beat both baselines where each is structurally
/// weak:
///
///  * the PS barrier gates *every* worker to the straggler's rate while
///    Ripples's fast workers keep free-running, so Ripples completes
///    strictly more cluster iterations in the window;
///  * AD-PSGD's random partner choice cannot avoid the straggler: the
///    slow passive keeps absorbing a near-uniform share of the sync
///    traffic, and the initiating actives — blocked on a partner's
///    in-flight step every iteration — fall well behind the free-running
///    passive, while no fast Ripples rank is gated at all.
#[test]
fn heterogeneous_straggler_ripples_beats_the_baselines() {
    let slow = Some((1usize, 3.0f64));
    let secs = 4.0;
    let run = |algo: AlgoKind| -> LaunchReport {
        let cfg = LaunchConfig { slow, secs, ..base(algo) };
        with_timeout(120, "hetero baseline run", move || {
            launch_local(&cfg).unwrap_or_else(|e| panic!("{} cluster run: {e:#}", algo.name()))
        })
    };
    let ripples = run(AlgoKind::RipplesSmart);
    let adpsgd = run(AlgoKind::AdPsgd);
    let ps = run(AlgoKind::ParameterServer);

    // all three still train through the straggler
    for r in [&ripples, &adpsgd, &ps] {
        assert_eq!(r.workers.len(), 4);
        for w in &r.workers {
            assert!(
                w.loss_last < w.loss_first * 0.85,
                "worker {} loss did not decrease: {} -> {}",
                w.rank,
                w.loss_first,
                w.loss_last
            );
        }
    }

    let iters = |r: &LaunchReport, rank: usize| r.workers[rank].iters as f64;
    let fast_mean = |r: &LaunchReport| -> f64 {
        let sum: f64 = r.workers.iter().filter(|w| w.rank != 1).map(|w| w.iters as f64).sum();
        sum / 3.0
    };

    // Ripples is not gated by the straggler (same bar as e2e_net)...
    assert!(
        fast_mean(&ripples) > 1.3 * iters(&ripples, 1),
        "ripples fast workers gated: fast mean {:.0} vs slow {:.0}",
        fast_mean(&ripples),
        iters(&ripples, 1)
    );
    // ...while the PS barrier locksteps everyone to the straggler's rate
    assert!(
        fast_mean(&ps) < 1.4 * iters(&ps, 1),
        "PS failed to gate (not a barrier?): fast mean {:.0} vs slow {:.0}",
        fast_mean(&ps),
        iters(&ps, 1)
    );
    // net effect: strictly more cluster work for Ripples in the window
    assert!(
        total_iters(&ripples) > total_iters(&ps),
        "ripples did not out-iterate the gated PS: {} vs {}",
        total_iters(&ripples),
        total_iters(&ps)
    );

    // AD-PSGD cannot steer around the straggler: the slow passive (rank
    // 1) still serves a near-uniform share of the exchanges the fast
    // passive (rank 3) gets (uniform random partner choice)...
    assert!(
        adpsgd.workers[1].preduces as f64 > 0.4 * adpsgd.workers[3].preduces as f64,
        "straggler stopped being picked (filtered?): serves {} vs {}",
        adpsgd.workers[1].preduces,
        adpsgd.workers[3].preduces
    );
    // ...and its initiating actives, blocked on a partner's in-flight
    // step (3x long half the time) every single iteration, fall well
    // behind their own free-running fast passive — the sync tax Ripples
    // avoids by scheduling stragglers out (no fast Ripples rank is gated,
    // asserted above).
    let active_max = iters(&adpsgd, 0).max(iters(&adpsgd, 2));
    assert!(
        iters(&adpsgd, 3) > 1.25 * active_max,
        "adpsgd actives were not dragged by the straggler: passive {:.0} vs \
         active max {:.0}",
        iters(&adpsgd, 3),
        active_max
    );
}
