//! Integration tests: whole-system behaviours across algorithm engines —
//! the paper's qualitative claims, determinism, and the config pipeline.

use ripples::bench::{self, base_params};
use ripples::config::{AlgoKind, Experiment};
use ripples::metrics;
use ripples::sim;

fn quick(kind: AlgoKind) -> sim::SimParams {
    let mut p = base_params(kind);
    p.exp.train.max_iters = 120;
    p.exp.train.loss_target = None;
    p
}

#[test]
fn paper_shape_homogeneous_ordering() {
    // Fig. 17 ordering on per-iteration time:
    //   ripples-{static,smart} < all-reduce < ps <= ad-psgd-ish
    let static_ = sim::run(&quick(AlgoKind::RipplesStatic));
    let smart = sim::run(&quick(AlgoKind::RipplesSmart));
    let ar = sim::run(&quick(AlgoKind::AllReduce));
    let ps = sim::run(&quick(AlgoKind::ParameterServer));
    let ad = sim::run(&quick(AlgoKind::AdPsgd));
    assert!(static_.per_iter_time() < ar.per_iter_time(), "static vs AR");
    assert!(smart.per_iter_time() < ar.per_iter_time(), "smart vs AR");
    assert!(ar.per_iter_time() < ps.per_iter_time(), "AR vs PS");
    assert!(smart.per_iter_time() < ad.per_iter_time(), "smart vs AD-PSGD");
}

#[test]
fn paper_shape_heterogeneous_flip() {
    // Fig. 1: AR >> AD-PSGD homo, AD-PSGD wins (or nearly) at 5x.
    let mut ar5 = quick(AlgoKind::AllReduce);
    ar5.exp.cluster.hetero.slow_worker = Some((7, 5.0));
    let mut ad5 = quick(AlgoKind::AdPsgd);
    ad5.exp.cluster.hetero.slow_worker = Some((7, 5.0));
    let ar_homo = sim::run(&quick(AlgoKind::AllReduce));
    let ar_hetero = sim::run(&ar5);
    let ad_homo = sim::run(&quick(AlgoKind::AdPsgd));
    let ad_hetero = sim::run(&ad5);
    // AR's per-iteration wall time balloons with the straggler...
    assert!(ar_hetero.per_iter_time() > 3.0 * ar_homo.per_iter_time());
    // ...AD-PSGD's barely moves.
    assert!(ad_hetero.per_iter_time() < 1.5 * ad_homo.per_iter_time());
}

#[test]
fn paper_shape_smart_gg_best_of_both() {
    // The headline: smart GG is near-best homo AND degrades mildly.
    let homo = sim::run(&quick(AlgoKind::RipplesSmart));
    let mut p5 = quick(AlgoKind::RipplesSmart);
    p5.exp.cluster.hetero.slow_worker = Some((7, 5.0));
    let hetero = sim::run(&p5);
    let degradation = hetero.final_time / homo.final_time;
    assert!(
        degradation < 2.0,
        "smart GG degraded {degradation}x under a 5x straggler"
    );
    let mut ar5 = quick(AlgoKind::AllReduce);
    ar5.exp.cluster.hetero.slow_worker = Some((7, 5.0));
    let ar_hetero = sim::run(&ar5);
    assert!(
        hetero.final_time < ar_hetero.final_time,
        "smart hetero {} vs AR hetero {}",
        hetero.final_time,
        ar_hetero.final_time
    );
}

#[test]
fn slow_worker_iterates_less_under_smart_gg() {
    // §5.3: the slowdown filter lets fast workers proceed; the slow
    // worker completes fewer iterations instead of dragging everyone.
    let mut p = quick(AlgoKind::RipplesSmart);
    p.exp.cluster.hetero.slow_worker = Some((3, 5.0));
    let res = sim::run(&p);
    let slow_iters = res.per_worker_iters[3];
    let fast_iters: Vec<u64> = res
        .per_worker_iters
        .iter()
        .enumerate()
        .filter(|&(w, _)| w != 3)
        .map(|(_, &it)| it)
        .collect();
    let fast_avg = fast_iters.iter().sum::<u64>() as f64 / fast_iters.len() as f64;
    assert!(
        (slow_iters as f64) < fast_avg * 0.6,
        "slow worker did {slow_iters} vs fast avg {fast_avg}"
    );
}

#[test]
fn static_blocks_on_straggler_more_than_smart() {
    // §4.3: the static schedule cannot route around a slow worker.
    let mut ps = quick(AlgoKind::RipplesStatic);
    ps.exp.cluster.hetero.slow_worker = Some((3, 5.0));
    let mut pm = quick(AlgoKind::RipplesSmart);
    pm.exp.cluster.hetero.slow_worker = Some((3, 5.0));
    let static_res = sim::run(&ps);
    let smart_res = sim::run(&pm);
    assert!(smart_res.final_time < static_res.final_time);
}

#[test]
fn convergence_time_to_target_all_algorithms() {
    // Every algorithm must actually reach the bench loss target.
    for &kind in AlgoKind::all() {
        let mut p = base_params(kind);
        p.exp.train.max_iters = 3000;
        let res = sim::run(&p);
        assert!(
            res.time_to_target.is_some(),
            "{kind:?} never reached {} (final {:?})",
            bench::LOSS_TARGET,
            res.trace.last().map(|t| t.loss)
        );
    }
}

#[test]
fn determinism_across_engines() {
    for &kind in AlgoKind::all() {
        let p = quick(kind);
        let a = sim::run(&p);
        let b = sim::run(&p);
        assert_eq!(a.final_time.to_bits(), b.final_time.to_bits(), "{kind:?}");
        assert_eq!(a.total_iters, b.total_iters, "{kind:?}");
        assert_eq!(a.conflicts, b.conflicts, "{kind:?}");
    }
}

#[test]
fn seeds_change_trajectories() {
    let mut p1 = quick(AlgoKind::RipplesSmart);
    let mut p2 = quick(AlgoKind::RipplesSmart);
    p1.exp.train.seed = 1;
    p2.exp.train.seed = 2;
    let a = sim::run(&p1);
    let b = sim::run(&p2);
    assert_ne!(
        a.trace.last().unwrap().loss,
        b.trace.last().unwrap().loss,
        "different seeds must explore different trajectories"
    );
}

#[test]
fn section_length_tradeoff_matches_fig16() {
    // Longer sections: faster per-iteration, more iterations to target.
    let mut p1 = base_params(AlgoKind::RipplesSmart);
    p1.exp.train.max_iters = 5000;
    p1.exp.train.eval_every = 2; // fine-grained so the crossing resolves
    let mut p16 = p1.clone();
    p16.exp.algo.section_len = 16;
    let r1 = sim::run(&p1);
    let r16 = sim::run(&p16);
    assert!(r16.per_iter_time() < r1.per_iter_time(), "throughput should rise");
    let i1 = r1.avg_iters_to_target.expect("section=1 must converge");
    let i16 = r16.avg_iters_to_target.expect("section=16 must converge");
    assert!(
        i16 > i1,
        "statistical efficiency should drop: {i1} vs {i16}"
    );
}

#[test]
fn group_size_tradeoff() {
    // §3.2: larger groups propagate updates faster (fewer iterations) but
    // increase conflict probability under random GG.
    let mut p2 = base_params(AlgoKind::RipplesRandom);
    p2.exp.algo.group_size = 2;
    p2.exp.train.max_iters = 400;
    p2.exp.train.loss_target = None;
    let mut p6 = p2.clone();
    p6.exp.algo.group_size = 6;
    let r2 = sim::run(&p2);
    let r6 = sim::run(&p6);
    assert!(
        r6.conflicts > r2.conflicts,
        "bigger groups must conflict more: {} vs {}",
        r6.conflicts,
        r2.conflicts
    );
}

#[test]
fn config_file_drives_simulation() {
    let dir = std::env::temp_dir().join("ripples_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        "[cluster]\nn_nodes = 2\nworkers_per_node = 2\nslow_worker = [1, 2.0]\n\
         [algo]\nkind = \"ripples-smart\"\ngroup_size = 2\n\
         [train]\nmax_iters = 50\nlr = 0.08\n",
    )
    .unwrap();
    let exp = Experiment::from_file(path.to_str().unwrap()).unwrap();
    assert_eq!(exp.cluster.n_workers(), 4);
    let mut params = sim::SimParams::vgg16_defaults(exp);
    params.spec = bench::bench_spec();
    params.dataset_size = 512;
    params.batch = 32;
    let res = sim::run(&params);
    assert_eq!(res.per_worker_iters.len(), 4);
    assert!(res.total_iters > 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trace_csv_and_summary_outputs() {
    let res = sim::run(&quick(AlgoKind::AllReduce));
    let line = metrics::summarize(&res);
    assert!(line.contains("all-reduce"));
    let dir = std::env::temp_dir().join("ripples_trace_test");
    let path = dir.join("t.csv");
    metrics::write_trace_csv(&res, &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.lines().count() > 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn dpsgd_converges_with_gossip_averaging() {
    let mut p = base_params(AlgoKind::DPsgd);
    p.exp.train.max_iters = 3000;
    let res = sim::run(&p);
    assert!(res.time_to_target.is_some(), "D-PSGD should converge");
}

#[test]
fn fixed_time_budget_ranking_matches_fig20() {
    // Under a fixed budget with ResNet-calibrated costs, AD-PSGD finishes
    // far fewer average iterations than All-Reduce or Prague smart.
    let budget = 300.0;
    let mut results = Vec::new();
    for kind in [AlgoKind::AllReduce, AlgoKind::AdPsgd, AlgoKind::RipplesSmart] {
        let mut exp = Experiment::default();
        exp.algo.kind = kind;
        exp.train.eval_every = 20;
        let mut p = sim::SimParams::resnet50_defaults(exp);
        p.spec = bench::bench_spec();
        p.dataset_size = 1024;
        p.batch = 32;
        let res = sim::run_time_budget(&p, budget);
        results.push((kind, res.total_iters as f64 / 16.0));
    }
    let get = |k: AlgoKind| results.iter().find(|(kk, _)| *kk == k).unwrap().1;
    assert!(
        get(AlgoKind::RipplesSmart) > get(AlgoKind::AdPsgd),
        "smart {} vs adpsgd {}",
        get(AlgoKind::RipplesSmart),
        get(AlgoKind::AdPsgd)
    );
}
