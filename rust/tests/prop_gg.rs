//! Property-based tests of the Group Generator, lock vector, static
//! scheduler, and collectives invariants.
//!
//! No proptest in the vendored registry, so this is a hand-rolled
//! randomized harness: every property runs across many PCG-seeded random
//! workloads; on failure the seed is in the panic message, making the
//! counterexample reproducible.

use std::collections::HashSet;

use ripples::collectives::{self, pipeline, ring};
use ripples::config::ClusterConfig;
use ripples::gg::{GgConfig, GroupGenerator, GroupId, StaticScheduler};
use ripples::util::rng::Pcg32;

const SEEDS: u64 = 40;

/// Drive a GG through a random request/complete workload, checking the
/// serialization invariants at every step.
fn gg_workload(cfg: GgConfig, seed: u64, steps: usize) {
    let n = cfg.n_workers;
    let mut gg = GroupGenerator::new(cfg);
    let mut rng = Pcg32::new(seed);
    // armed groups we have not yet completed: (id, members)
    let mut armed: Vec<(GroupId, Vec<usize>)> = Vec::new();
    // workers currently waiting (requested, assigned group not completed)
    let mut waiting: HashSet<usize> = HashSet::new();

    for step in 0..steps {
        let do_request = armed.is_empty() || rng.gen_f64() < 0.6;
        if do_request {
            // pick a worker that is not already waiting
            let free: Vec<usize> = (0..n).filter(|w| !waiting.contains(w)).collect();
            if free.is_empty() {
                // must complete something; fall through
            } else {
                let w = free[rng.gen_range(free.len())];
                let (gid, newly) = gg.request(w, &mut rng);
                match gid {
                    // None = "skip this sync step" (no idle partner);
                    // must come with no new groups
                    None => assert!(
                        newly.is_empty(),
                        "seed {seed} step {step}: groups without assignment"
                    ),
                    Some(gid) => {
                        waiting.insert(w);
                        let g = gg.group(gid).unwrap_or_else(|| {
                            panic!("seed {seed} step {step}: assigned group {gid} unknown")
                        });
                        assert!(
                            g.members.contains(&w),
                            "seed {seed} step {step}: group {:?} lacks requester {w}",
                            g.members
                        );
                        for g in newly {
                            armed.push((g.id, g.members));
                        }
                    }
                }
            }
        }
        if !do_request || waiting.len() == n {
            if let Some(idx) = (!armed.is_empty()).then(|| rng.gen_range(armed.len())) {
                let (gid, members) = armed.swap_remove(idx);
                let newly = gg.complete(gid);
                for &m in &members {
                    waiting.remove(&m);
                }
                for g in newly {
                    armed.push((g.id, g.members));
                }
            }
        }
        // ---- invariants ----
        // 1. armed groups are pairwise disjoint (atomicity)
        let mut seen: HashSet<usize> = HashSet::new();
        for (gid, members) in &armed {
            for &m in members {
                assert!(
                    seen.insert(m),
                    "seed {seed} step {step}: worker {m} in two armed groups (g{gid})"
                );
            }
        }
        // 2. every armed member's lock bit is set — and pending groups are
        //    exactly the live groups that are not armed
        let armed_ids: HashSet<GroupId> = armed.iter().map(|&(id, _)| id).collect();
        for gid in gg.live_group_ids() {
            assert_eq!(
                gg.is_armed(gid),
                armed_ids.contains(&gid),
                "seed {seed} step {step}: armed-state mismatch for g{gid}"
            );
        }
        // 3. counter sum equals request count
        let csum: u64 = gg.counters().iter().sum();
        assert_eq!(csum, gg.stats.requests, "seed {seed} step {step}");
    }
    // ---- drain: completing everything must release all locks ----
    while let Some((gid, _)) = armed.pop() {
        for g in gg.complete(gid) {
            armed.push((g.id, g.members));
        }
    }
    assert_eq!(gg.pending_len(), 0, "seed {seed}: pending groups leaked");
}

#[test]
fn prop_random_gg_serialization_invariants() {
    for seed in 0..SEEDS {
        gg_workload(GgConfig::random(16, 4, 3), seed, 300);
    }
}

#[test]
fn prop_smart_gg_serialization_invariants() {
    for seed in 0..SEEDS {
        gg_workload(GgConfig::smart(16, 4, 3, 8), seed, 300);
    }
}

#[test]
fn prop_gg_various_shapes_and_group_sizes() {
    let mut rng = Pcg32::new(999);
    for seed in 0..SEEDS {
        let nodes = 1 + rng.gen_range(6);
        let wpn = 1 + rng.gen_range(6);
        let n = (nodes * wpn).max(2);
        let k = 2 + rng.gen_range((n - 1).min(6));
        gg_workload(GgConfig::random(n, wpn, k), seed, 150);
        gg_workload(GgConfig::smart(n, wpn, k, 4), seed, 150);
    }
}

/// Drive a GG through a random interleaving of request / complete /
/// declare_dead, checking the death-purge invariants at every step:
/// no lock is ever held by a dead rank, no Group Buffer entry (of any
/// worker) names a group containing a dead rank, the speed table forgets
/// dead ranks, and `GgStats` reflects every purge.
fn gg_death_workload(cfg: ripples::gg::GgConfig, seed: u64, steps: usize) {
    let n = cfg.n_workers;
    let use_gb = cfg.use_group_buffer;
    let mut gg = GroupGenerator::new(cfg);
    let mut rng = Pcg32::new(seed ^ 0xDead);
    let mut armed: Vec<(GroupId, Vec<usize>)> = Vec::new();
    let mut waiting: HashSet<usize> = HashSet::new();
    let mut dead: HashSet<usize> = HashSet::new();
    // seed some telemetry so the purge has something to erase
    for w in 0..n {
        gg.report_speed(w, 0.010 + 0.001 * w as f64);
    }

    for step in 0..steps {
        let roll = rng.gen_f64();
        if roll < 0.08 && dead.len() + 2 < n {
            // ---- declare a random live rank dead
            let live: Vec<usize> = (0..n).filter(|w| !dead.contains(w)).collect();
            let victim = live[rng.gen_range(live.len())];
            let purge = gg.declare_dead(victim);
            dead.insert(victim);
            waiting.remove(&victim);
            let aborted: HashSet<GroupId> = purge.aborted.iter().map(|g| g.id).collect();
            armed.retain(|(id, _)| !aborted.contains(id));
            for g in &purge.aborted {
                for m in &g.members {
                    // stranded members would re-sync; model as not waiting
                    waiting.remove(m);
                }
            }
            for g in purge.newly_armed {
                armed.push((g.id, g.members));
            }
        } else if roll < 0.6 || armed.is_empty() {
            // ---- a live, non-waiting worker requests
            let free: Vec<usize> =
                (0..n).filter(|w| !waiting.contains(w) && !dead.contains(w)).collect();
            if let Some(&w) = (!free.is_empty())
                .then(|| &free[rng.gen_range(free.len())])
            {
                let (gid, newly) = gg.request(w, &mut rng);
                if let Some(gid) = gid {
                    waiting.insert(w);
                    let g = gg.group(gid).unwrap_or_else(|| {
                        panic!("seed {seed} step {step}: assigned {gid} unknown")
                    });
                    assert!(
                        !g.members.iter().any(|m| dead.contains(m)),
                        "seed {seed} step {step}: dead rank in assigned {:?}",
                        g.members
                    );
                }
                for g in newly {
                    armed.push((g.id, g.members));
                }
            }
        } else {
            // ---- complete a random armed group
            let idx = rng.gen_range(armed.len());
            let (gid, members) = armed.swap_remove(idx);
            for &m in &members {
                waiting.remove(&m);
            }
            for g in gg.complete(gid) {
                armed.push((g.id, g.members));
            }
        }
        // ---- invariants after every step ----
        for &d in &dead {
            assert!(
                !gg.is_locked_worker(d),
                "seed {seed} step {step}: dead rank {d} holds a lock"
            );
            assert!(
                gg.gb_snapshot(d).is_empty(),
                "seed {seed} step {step}: dead rank {d} has GB entries"
            );
            assert_eq!(
                gg.speed_table().get(d),
                None,
                "seed {seed} step {step}: dead rank {d} still measured"
            );
            assert!(gg.is_dead(d) && gg.is_retired(d));
        }
        for gid in gg.live_group_ids() {
            let members = &gg.group(gid).unwrap().members;
            assert!(
                !members.iter().any(|m| dead.contains(m)),
                "seed {seed} step {step}: live group {gid} names a dead rank {members:?}"
            );
        }
        if use_gb {
            // every GB entry refers to a live group (hence dead-free)
            for w in 0..n {
                for gid in gg.gb_snapshot(w) {
                    assert!(
                        gg.group(gid).is_some(),
                        "seed {seed} step {step}: GB of {w} names dead/stale group {gid}"
                    );
                }
            }
        }
        assert_eq!(
            gg.stats.deaths as usize,
            dead.len(),
            "seed {seed} step {step}: death count drifted"
        );
    }
    // drain and verify no lock leaks
    while let Some((gid, _)) = armed.pop() {
        for g in gg.complete(gid) {
            armed.push((g.id, g.members));
        }
    }
    assert_eq!(gg.pending_len(), 0, "seed {seed}: pending groups leaked");
    assert_eq!(gg.locked_count(), 0, "seed {seed}: locks leaked after drain");
}

#[test]
fn prop_death_purge_invariants_random_gg() {
    for seed in 0..SEEDS {
        gg_death_workload(GgConfig::random(16, 4, 3), seed, 250);
    }
}

#[test]
fn prop_death_purge_invariants_smart_gg() {
    for seed in 0..SEEDS {
        gg_death_workload(GgConfig::smart(16, 4, 3, 8), seed, 250);
    }
}

/// Differential fuzz: the sharded scale-out coordinator vs the
/// single-lock oracle under ONE interleaved op stream — Sync, Complete,
/// declare_dead, rejoin, retire, report_speed, abort_group. Assignments,
/// armed sets, purges, and stats must be identical per op (the sharded
/// path sequences its mutators so RNG consumption and stat ordering
/// match the oracle exactly); every 8 ops the full observable state is
/// swept. Seed and step are in every panic message.
fn gg_differential_workload(cfg: GgConfig, seed: u64, steps: usize) {
    use ripples::gg::ShardedGg;
    let n = cfg.n_workers;
    let mut oracle = GroupGenerator::new(cfg.clone());
    let mut orng = Pcg32::new(seed);
    let sharded = ShardedGg::new(cfg.clone(), seed);
    let mut ops = Pcg32::new(seed ^ 0xD1FF);
    let mut armed: Vec<GroupId> = Vec::new();
    // Vec (not HashSet): choices must replay identically across runs
    let mut dead: Vec<usize> = Vec::new();

    let full_sweep = |oracle: &GroupGenerator, sharded: &ShardedGg, step: usize| {
        assert_eq!(
            format!("{:?}", oracle.stats),
            format!("{:?}", sharded.stats()),
            "seed {seed} step {step}: stats diverged"
        );
        assert_eq!(oracle.counters(), &sharded.counters()[..], "seed {seed} step {step}");
        assert_eq!(oracle.drafts(), &sharded.drafts()[..], "seed {seed} step {step}");
        assert_eq!(
            oracle.last_drafted(),
            &sharded.last_drafted()[..],
            "seed {seed} step {step}"
        );
        assert_eq!(oracle.pending_len(), sharded.pending_len(), "seed {seed} step {step}");
        assert_eq!(
            oracle.locked_count(),
            sharded.locked_count(),
            "seed {seed} step {step}"
        );
        let mut a_live = oracle.live_group_ids();
        let mut b_live = sharded.live_group_ids();
        a_live.sort_unstable();
        b_live.sort_unstable();
        assert_eq!(a_live, b_live, "seed {seed} step {step}: live groups diverged");
        assert_eq!(
            oracle.speed_table().snapshot(),
            sharded.speed_snapshot(),
            "seed {seed} step {step}: speed tables diverged"
        );
        for w in 0..n {
            assert_eq!(
                oracle.gb_snapshot(w),
                sharded.gb_snapshot(w),
                "seed {seed} step {step}: GB of {w} diverged"
            );
            assert_eq!(
                oracle.is_locked_worker(w),
                sharded.is_locked_worker(w),
                "seed {seed} step {step}: lock bit of {w} diverged"
            );
            assert_eq!(oracle.is_dead(w), sharded.is_dead(w), "seed {seed} step {step}");
            assert_eq!(
                oracle.is_retired(w),
                sharded.is_retired(w),
                "seed {seed} step {step}"
            );
        }
    };

    for step in 0..steps {
        let roll = ops.gen_f64();
        if roll < 0.50 {
            // ---- Sync from a random live rank
            let live: Vec<usize> = (0..n).filter(|w| !dead.contains(w)).collect();
            if !live.is_empty() {
                let w = live[ops.gen_range(live.len())];
                let (aa, ag) = oracle.request(w, &mut orng);
                let (ba, bg) = sharded.request(w);
                assert_eq!(aa, ba, "seed {seed} step {step}: assignment diverged");
                assert_eq!(ag, bg, "seed {seed} step {step}: armed set diverged");
                armed.extend(ag.iter().map(|g| g.id));
            }
        } else if roll < 0.70 {
            // ---- Complete a random armed group
            if !armed.is_empty() {
                let id = armed.swap_remove(ops.gen_range(armed.len()));
                let a = oracle.complete(id);
                let b = sharded.complete(id);
                assert_eq!(a, b, "seed {seed} step {step}: complete({id}) diverged");
                armed.extend(a.iter().map(|g| g.id));
            }
        } else if roll < 0.76 {
            // ---- declare a random live rank dead (keep 2 alive)
            if dead.len() + 2 < n {
                let live: Vec<usize> = (0..n).filter(|w| !dead.contains(w)).collect();
                let victim = live[ops.gen_range(live.len())];
                let a = oracle.declare_dead(victim);
                let b = sharded.declare_dead(victim);
                assert_eq!(
                    format!("{a:?}"),
                    format!("{b:?}"),
                    "seed {seed} step {step}: death purge of {victim} diverged"
                );
                dead.push(victim);
                armed.extend(a.newly_armed.iter().map(|g| g.id));
            }
        } else if roll < 0.82 {
            // ---- rejoin a dead rank (or, rarely, a live one — that
            // purges and revives in both)
            let w = if !dead.is_empty() && ops.gen_f64() < 0.8 {
                dead.swap_remove(ops.gen_range(dead.len()))
            } else {
                ops.gen_range(n)
            };
            dead.retain(|&d| d != w);
            let a = oracle.rejoin(w);
            let b = sharded.rejoin(w);
            assert_eq!(
                format!("{a:?}"),
                format!("{b:?}"),
                "seed {seed} step {step}: rejoin purge of {w} diverged"
            );
            armed.extend(a.newly_armed.iter().map(|g| g.id));
        } else if roll < 0.88 {
            // ---- abort a random armed group (failure repair path)
            if !armed.is_empty() {
                let id = armed.swap_remove(ops.gen_range(armed.len()));
                let a = oracle.abort_group(id);
                let b = sharded.abort_group(id);
                assert_eq!(a, b, "seed {seed} step {step}: abort({id}) diverged");
                assert_eq!(
                    oracle.was_aborted(id),
                    sharded.was_aborted(id),
                    "seed {seed} step {step}"
                );
                armed.extend(a.iter().map(|g| g.id));
            }
        } else if roll < 0.94 {
            // ---- retire a random rank
            let w = ops.gen_range(n);
            oracle.retire(w);
            sharded.retire(w);
        } else {
            // ---- piggybacked speed report (same value to both)
            let w = ops.gen_range(n);
            let s = 0.005 + 0.040 * ops.gen_f64();
            oracle.report_speed(w, s);
            sharded.report_speed(w, s);
        }
        // purges/aborts may have torn down groups still in our list
        armed.retain(|&id| oracle.is_armed(id));
        armed.sort_unstable();
        armed.dedup();
        if step % 8 == 0 {
            full_sweep(&oracle, &sharded, step);
        }
    }
    full_sweep(&oracle, &sharded, steps);
    // drain both and verify neither leaks
    while let Some(id) = armed.pop() {
        let a = oracle.complete(id);
        let b = sharded.complete(id);
        assert_eq!(a, b, "seed {seed} drain: complete({id}) diverged");
        armed.extend(a.iter().map(|g| g.id));
    }
    assert_eq!(oracle.pending_len(), 0, "seed {seed}: oracle leaked pending");
    assert_eq!(sharded.pending_len(), 0, "seed {seed}: sharded leaked pending");
    assert_eq!(sharded.locked_count(), 0, "seed {seed}: sharded leaked locks");
}

#[test]
fn prop_sharded_gg_differentially_equal_random() {
    for seed in 0..SEEDS {
        gg_differential_workload(GgConfig::random(16, 4, 3), seed, 250);
    }
}

#[test]
fn prop_sharded_gg_differentially_equal_smart() {
    for seed in 0..SEEDS {
        gg_differential_workload(GgConfig::smart(16, 4, 3, 8), seed, 250);
    }
}

#[test]
fn prop_sharded_gg_differentially_equal_various_shapes() {
    let mut rng = Pcg32::new(0x5ca1e);
    for seed in 0..SEEDS {
        let nodes = 1 + rng.gen_range(6);
        let wpn = 1 + rng.gen_range(6);
        let n = (nodes * wpn).max(3);
        let k = 2 + rng.gen_range((n - 1).min(5));
        gg_differential_workload(GgConfig::random(n, wpn, k), seed, 120);
        let mut smart = GgConfig::smart(n, wpn, k, 4);
        smart.rendezvous = seed % 2 == 0;
        gg_differential_workload(smart, seed, 120);
    }
}

/// Identical crash schedules replay bit-for-bit: the fault-injection
/// backbone's reproducibility guarantee, end to end through the
/// simulator (crash, repair, rejoin, loss trace).
#[test]
fn prop_sim_crash_schedules_deterministic() {
    use ripples::cluster::CrashEvent;
    use ripples::config::{AlgoKind, Experiment};
    use ripples::model::MlpSpec;
    use ripples::sim::{self, SimParams};
    for seed in 0..6u64 {
        let mut exp = Experiment::default();
        exp.algo.kind = AlgoKind::RipplesSmart;
        exp.train.max_iters = 60;
        exp.train.eval_every = 10;
        exp.train.loss_target = None;
        exp.train.seed = 1000 + seed;
        let mut rng = Pcg32::new(seed ^ 0xC4A5);
        exp.cluster.hetero.crashes = vec![CrashEvent {
            worker: rng.gen_range(16),
            at_iter: 5 + rng.gen_range(30) as u64,
            rejoin_after_secs: (seed % 2 == 0).then_some(2.5),
        }];
        let mut p = SimParams::vgg16_defaults(exp);
        p.spec = MlpSpec::tiny();
        p.dataset_size = 256;
        p.batch = 32;
        let a = sim::run(&p);
        let b = sim::run(&p);
        assert_eq!(a.final_time.to_bits(), b.final_time.to_bits(), "seed {seed}");
        assert_eq!(a.per_worker_iters, b.per_worker_iters, "seed {seed}");
        assert_eq!(a.deaths, b.deaths, "seed {seed}");
        assert_eq!(a.rejoins, b.rejoins, "seed {seed}");
        assert_eq!(a.groups_aborted, b.groups_aborted, "seed {seed}");
        assert_eq!(a.trace.len(), b.trace.len(), "seed {seed}");
        for (x, y) in a.trace.iter().zip(b.trace.iter()) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "seed {seed}");
        }
        assert!(a.deaths == 1, "seed {seed}: the crash must fire");
    }
}

#[test]
fn prop_global_division_partitions_are_disjoint() {
    for seed in 0..SEEDS {
        let mut cfg = GgConfig::smart(16, 4, 3, 1_000_000);
        cfg.inter_intra = seed % 2 == 0;
        let mut gg = GroupGenerator::new(cfg);
        let mut rng = Pcg32::new(seed);
        let w = rng.gen_range(16);
        let (_, armed) = gg.request(w, &mut rng);
        // armed groups must be disjoint among themselves (lock exclusivity)
        let mut seen = HashSet::new();
        for g in &armed {
            for &m in &g.members {
                assert!(seen.insert(m), "seed {seed}: GD overlap at {m}");
            }
        }
        if gg.config().inter_intra {
            // the *intra*-phase groups deliberately queue behind the
            // inter-phase groups holding the locks — they are the
            // "conflicts" here, and must equal the pending count
            assert_eq!(
                gg.stats.conflicts as usize,
                gg.pending_len(),
                "seed {seed}: pending bookkeeping"
            );
        } else {
            // plain GD: a partition can never conflict with itself
            assert_eq!(gg.stats.conflicts, 0, "seed {seed}: GD must not conflict");
        }
    }
}

/// With measured speeds replacing configured ones, the slowdown filter
/// excludes *exactly* the workers whose EWMA exceeds the threshold: a
/// fast initiator's Global Division drafts every idle worker at or
/// under `s_thres` times the fastest EWMA and nobody above it.
#[test]
fn prop_measured_filter_excludes_exactly_over_threshold() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0x5eed);
        let n = 4 + rng.gen_range(13);
        let mut cfg = GgConfig::smart(n, 4, 2 + rng.gen_range(3), 1_000_000);
        cfg.inter_intra = false; // plain GD: the filter is the only exclusion
        let s_thres = cfg.s_thres.expect("smart preset enables the measured filter");
        let mut gg = GroupGenerator::new(cfg);
        // random measured EWMAs between 10ms and 40ms (up to 4x spread)
        let speeds: Vec<f64> = (0..n).map(|_| 0.010 + 0.030 * rng.gen_f64()).collect();
        for (w, &s) in speeds.iter().enumerate() {
            gg.report_speed(w, s);
        }
        let reference = speeds.iter().cloned().fold(f64::INFINITY, f64::min);
        let initiator = (0..n)
            .min_by(|&a, &b| speeds[a].partial_cmp(&speeds[b]).unwrap())
            .unwrap();
        let expected: Vec<usize> = (0..n)
            .filter(|&x| x == initiator || speeds[x] / reference <= s_thres)
            .collect();
        let (_, armed) = gg.request(initiator, &mut rng);
        let mut drafted: Vec<usize> =
            armed.iter().flat_map(|g| g.members.iter().copied()).collect();
        drafted.sort_unstable();
        if expected.len() >= 2 {
            assert_eq!(
                drafted, expected,
                "seed {seed}: filter drafted the wrong set (speeds {speeds:?})"
            );
        } else {
            assert!(drafted.is_empty(), "seed {seed}: degenerate division must skip");
        }
    }
}

/// Retired ranks must not anchor the speed reference: with the fastest
/// worker retired, the filter judges everyone against the fastest *live*
/// EWMA — exactly the workers within `s_thres` of it are drafted.
#[test]
fn prop_retired_ranks_excluded_from_speed_reference() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0xde7e);
        let n = 4 + rng.gen_range(13);
        let mut cfg = GgConfig::smart(n, 4, 2 + rng.gen_range(3), 1_000_000);
        cfg.inter_intra = false;
        let s_thres = cfg.s_thres.expect("smart preset enables the measured filter");
        let mut gg = GroupGenerator::new(cfg);
        let speeds: Vec<f64> = (0..n).map(|_| 0.010 + 0.030 * rng.gen_f64()).collect();
        for (w, &s) in speeds.iter().enumerate() {
            gg.report_speed(w, s);
        }
        // retire the fastest worker: its frozen EWMA must stop mattering
        let fastest = (0..n)
            .min_by(|&a, &b| speeds[a].partial_cmp(&speeds[b]).unwrap())
            .unwrap();
        gg.retire(fastest);
        let live_ref = (0..n)
            .filter(|&w| w != fastest)
            .map(|w| speeds[w])
            .fold(f64::INFINITY, f64::min);
        let initiator = (0..n)
            .filter(|&w| w != fastest)
            .min_by(|&a, &b| speeds[a].partial_cmp(&speeds[b]).unwrap())
            .unwrap();
        let expected: Vec<usize> = (0..n)
            .filter(|&x| {
                x != fastest && (x == initiator || speeds[x] / live_ref <= s_thres)
            })
            .collect();
        let (_, armed) = gg.request(initiator, &mut rng);
        let mut drafted: Vec<usize> =
            armed.iter().flat_map(|g| g.members.iter().copied()).collect();
        drafted.sort_unstable();
        if expected.len() >= 2 {
            assert_eq!(
                drafted, expected,
                "seed {seed}: wrong live-reference set (fastest {fastest} retired, \
                 speeds {speeds:?})"
            );
        } else {
            assert!(drafted.is_empty(), "seed {seed}: degenerate division must skip");
        }
    }
}

/// The overlap pipeline's shard partition exactly tiles the model for
/// every ragged size: contiguous, in order, no gaps, no overlap, and
/// balanced to within one element.
#[test]
fn prop_shard_partition_tiles_ragged_sizes() {
    let mut rng = Pcg32::new(0x5a4d);
    for _ in 0..SEEDS * 4 {
        let n = rng.gen_range(5000);
        let k = 1 + rng.gen_range(16);
        let mut covered = 0usize;
        let mut sizes = Vec::new();
        for s in 0..k {
            let (lo, hi) = pipeline::shard_bounds(n, k, s);
            assert_eq!(lo, covered, "gap/overlap at n={n} k={k} s={s}");
            assert!(hi >= lo, "negative shard at n={n} k={k} s={s}");
            sizes.push(hi - lo);
            covered = hi;
        }
        assert_eq!(covered, n, "partition does not tile n={n} k={k}");
        let (min, max) = (
            sizes.iter().copied().min().unwrap(),
            sizes.iter().copied().max().unwrap(),
        );
        assert!(max - min <= 1, "unbalanced shards {sizes:?} at n={n} k={k}");
    }
}

/// The sharded (pipelined) ring equals the naive mean on random ragged
/// shapes — shard count included in the fuzz.
#[test]
fn prop_sharded_ring_matches_naive() {
    use ripples::collectives::ring::ChannelTransport;
    use std::thread;
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0x0f00);
        let p = 2 + rng.gen_range(6);
        let n = 1 + rng.gen_range(600);
        let k = 1 + rng.gen_range(9);
        let mut bufs: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..n).map(|_| rng.gen_f32() * 2.0 - 1.0).collect())
            .collect();
        let expect: Vec<f32> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>() / p as f32)
            .collect();
        let transports = ChannelTransport::ring(p);
        thread::scope(|scope| {
            for ((r, buf), mut t) in bufs.iter_mut().enumerate().zip(transports) {
                scope.spawn(move || {
                    pipeline::ring_allreduce_sharded(r, p, buf, k, &mut t, |_, _| ())
                        .expect("sharded ring");
                });
            }
        });
        for (r, buf) in bufs.iter().enumerate() {
            for i in 0..n {
                assert!(
                    (buf[i] - expect[i]).abs() < 1e-4,
                    "seed {seed} p={p} n={n} k={k} rank={r} idx={i}"
                );
            }
        }
    }
}

#[test]
fn prop_static_schedule_conflict_free_and_consistent() {
    let mut rng = Pcg32::new(4242);
    for _ in 0..SEEDS {
        let nodes = 1 + rng.gen_range(8);
        let wpn = 1 + rng.gen_range(8);
        let s = StaticScheduler::new(nodes, wpn);
        for iter in 0..12u64 {
            let mut seen = vec![false; s.n_workers()];
            for w in 0..s.n_workers() {
                if let Some(g) = s.group_of(w, iter) {
                    // consistency
                    for &m in &g {
                        assert_eq!(
                            s.group_of(m, iter),
                            Some(g.clone()),
                            "({nodes},{wpn}) iter {iter}: inconsistent view"
                        );
                    }
                    // conflict-freedom (count each worker once via leader)
                    if g[0] == w {
                        for &m in &g {
                            assert!(!seen[m], "({nodes},{wpn}) iter {iter}: overlap");
                            seen[m] = true;
                        }
                    }
                }
            }
        }
    }
}

/// F^G applied to random replica ensembles: doubly-stochastic mass
/// conservation and contraction of disagreement.
#[test]
fn prop_preduce_doubly_stochastic_and_contractive() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed);
        let n_workers = 2 + rng.gen_range(14);
        let dim = 1 + rng.gen_range(200);
        let mut models: Vec<Vec<f32>> = (0..n_workers)
            .map(|_| (0..dim).map(|_| rng.gen_f32() * 4.0 - 2.0).collect())
            .collect();
        let total_before: f64 = models
            .iter()
            .flat_map(|m| m.iter())
            .map(|&v| v as f64)
            .sum();
        let spread = |models: &[Vec<f32>]| -> f64 {
            let mut worst = 0.0f64;
            for i in 0..dim {
                let vals: Vec<f64> = models.iter().map(|m| m[i] as f64).collect();
                let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                worst = worst.max(hi - lo);
            }
            worst
        };
        let before = spread(&models);
        let mut scratch = Vec::new();
        for _ in 0..10 {
            let k = 2 + rng.gen_range((n_workers - 1).min(4));
            let group = rng.sample_distinct(n_workers, k);
            let mut sorted = group.clone();
            sorted.sort_unstable();
            // borrow-split members
            let mut refs: Vec<&mut [f32]> = Vec::new();
            let mut rest: &mut [Vec<f32>] = &mut models;
            let mut off = 0;
            for &g in &sorted {
                let (head, tail) = rest.split_at_mut(g - off + 1);
                refs.push(head[g - off].as_mut_slice());
                rest = tail;
                off = g + 1;
            }
            collectives::preduce_mean_inplace(&mut refs, &mut scratch);
        }
        let total_after: f64 = models
            .iter()
            .flat_map(|m| m.iter())
            .map(|&v| v as f64)
            .sum();
        assert!(
            (total_before - total_after).abs() < 1e-2 * (1.0 + total_before.abs()),
            "seed {seed}: mass {total_before} -> {total_after}"
        );
        assert!(
            spread(&models) <= before + 1e-6,
            "seed {seed}: disagreement grew"
        );
    }
}

/// Ring all-reduce (threaded, chunked) equals the naive mean on random
/// shapes, including n < p and odd sizes.
#[test]
fn prop_ring_allreduce_matches_naive() {
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0xff);
        let p = 2 + rng.gen_range(7);
        let n = 1 + rng.gen_range(600);
        let mut bufs: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..n).map(|_| rng.gen_f32() * 2.0 - 1.0).collect())
            .collect();
        let expect: Vec<f32> = (0..n)
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>() / p as f32)
            .collect();
        ring::ring_allreduce_mean(&mut bufs);
        for (r, buf) in bufs.iter().enumerate() {
            for i in 0..n {
                assert!(
                    (buf[i] - expect[i]).abs() < 1e-4,
                    "seed {seed} p={p} n={n} rank={r} idx={i}"
                );
            }
        }
    }
}

/// Cost-model sanity across random topologies: ring all-reduce time is
/// monotone in message size and never cheaper for a superset group.
#[test]
fn prop_cost_model_monotonicity() {
    use ripples::comm::CostModel;
    for seed in 0..SEEDS {
        let mut rng = Pcg32::new(seed ^ 0xabc);
        let cluster = ClusterConfig {
            n_nodes: 1 + rng.gen_range(6),
            workers_per_node: 1 + rng.gen_range(6),
            ..ClusterConfig::default()
        };
        let cost = CostModel::from_cluster(&cluster);
        let n = cluster.n_workers();
        if n < 3 {
            continue;
        }
        let k = 2 + rng.gen_range(n - 2);
        let group = {
            let mut g = rng.sample_distinct(n, k);
            g.sort_unstable();
            g
        };
        let small = cost.ring_allreduce(&group, 1 << 20);
        let big = cost.ring_allreduce(&group, 1 << 24);
        assert!(big > small, "seed {seed}: cost not monotone in bytes");
        let mut superset = group.clone();
        for w in 0..n {
            if !superset.contains(&w) {
                superset.push(w);
                break;
            }
        }
        if superset.len() > group.len() {
            superset.sort_unstable();
            assert!(
                cost.ring_allreduce(&superset, 1 << 20) >= small * 0.9,
                "seed {seed}: superset group drastically cheaper"
            );
        }
    }
}
