//! Concurrency stress suite for the sharded Group Generator and the
//! reactor RPC plane (`make stress`). Unlike `prop_gg`'s sequential
//! differential fuzz, every test here hammers ONE coordinator from many
//! real threads at once and checks the paper's serialization invariants
//! *while* the races are live:
//!
//!   * no rank is ever a member of two armed groups (LockVector
//!     exclusivity), detected via a per-rank owner ledger of CAS'd
//!     group ids — a double grant fails the CAS with both ids in hand;
//!   * per-rank Group Buffer FIFO: the assigned group ids a rank
//!     observes are non-decreasing (creation order; an older group never
//!     surfaces after a newer one);
//!   * death/rejoin chaos leaves no leaked locks, no lock bit on a dead
//!     rank, and a fully drainable group table;
//!   * 64 real TCP clients against one reactor-served `GgServer` are
//!     each served exactly once per Sync.
//!
//! Everything is bounded (iteration counts, IO timeouts) so a deadlock
//! fails loudly instead of hanging the suite; `make stress` adds a hard
//! `timeout` on top.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use ripples::gg::{GgConfig, ShardedGg};

/// T = n_workers threads, each exclusively driving its own rank through
/// `iters` request + transitive-complete rounds against one shared
/// [`ShardedGg`].
///
/// Owner ledger: each newly-armed group is returned to exactly one
/// caller (the op that armed it); that caller CASes every member's cell
/// `0 -> gid` on delivery and stores `0` back *before* completing the
/// group. While a group is armed its members' locks are held, so no
/// other group naming them can arm — any failed CAS is a genuine double
/// grant, and the panic carries both group ids.
fn hammer(cfg: GgConfig, iters: usize) {
    let n = cfg.n_workers;
    let gg = Arc::new(ShardedGg::new(cfg, 0xABBA));
    let ledger: Arc<Vec<AtomicU64>> = Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());

    std::thread::scope(|scope| {
        for w in 0..n {
            let gg = Arc::clone(&gg);
            let ledger = Arc::clone(&ledger);
            scope.spawn(move || {
                let mut last_assigned = 0u64; // gids start at 1
                for _ in 0..iters {
                    let (assigned, newly) = gg.request(w);
                    if let Some(gid) = assigned {
                        // non-decreasing: a still-open buffer front is
                        // legitimately re-served, but an *older* group
                        // must never surface after a newer one (GB FIFO
                        // + monotone creation ids)
                        assert!(
                            gid >= last_assigned,
                            "rank {w}: GB FIFO violated ({gid} after {last_assigned})"
                        );
                        last_assigned = gid;
                    }
                    // transitively complete every group this thread owns
                    let mut todo = newly;
                    while let Some(g) = todo.pop() {
                        for &m in &g.members {
                            let prev = ledger[m].compare_exchange(
                                0,
                                g.id,
                                Ordering::AcqRel,
                                Ordering::Acquire,
                            );
                            if let Err(other) = prev {
                                panic!(
                                    "rank {m} granted to two armed groups at once: \
                                     g{} and g{other}",
                                    g.id
                                );
                            }
                        }
                        // release the ledger BEFORE complete(): the locks
                        // are still held here, so no concurrent arm of
                        // these members can race the store
                        for &m in &g.members {
                            let prev = ledger[m].swap(0, Ordering::AcqRel);
                            assert_eq!(prev, g.id, "ledger of {m} corrupted");
                        }
                        todo.extend(gg.complete(g.id));
                    }
                }
            });
        }
    });

    // quiesce: complete whatever armed groups remained undelivered-as-
    // completable (threads exited mid-chain), then nothing may leak
    drain(&gg);
    assert_eq!(gg.pending_len(), 0, "pending groups leaked");
    assert_eq!(gg.locked_count(), 0, "locks leaked");
    let stats = gg.stats();
    assert_eq!(stats.requests, (n * iters) as u64, "requests lost or duplicated");
    let csum: u64 = gg.counters().iter().sum();
    assert_eq!(csum, stats.requests, "per-worker counters drifted");
}

/// Complete every live armed group until the table is empty (completing
/// armed groups frees locks, which arms pending ones). Bounded so a
/// stuck table panics instead of spinning forever.
fn drain(gg: &ShardedGg) {
    for _ in 0..100_000 {
        let live = gg.live_group_ids();
        if live.is_empty() {
            return;
        }
        let mut progressed = false;
        for id in live {
            if gg.is_armed(id) {
                gg.complete(id);
                progressed = true;
            }
        }
        assert!(progressed, "live groups remain but none are armed: stuck table");
    }
    panic!("drain did not converge");
}

#[test]
fn stress_no_double_grants_random_gg() {
    hammer(GgConfig::random(16, 4, 3), 400);
}

#[test]
fn stress_no_double_grants_smart_gg() {
    // GB + GD + inter-intra: the buffer-hit fast path and the division
    // path race each other here
    hammer(GgConfig::smart(16, 4, 3, 8), 400);
}

#[test]
fn stress_no_double_grants_rendezvous_gg() {
    let mut cfg = GgConfig::random(12, 4, 3);
    cfg.rendezvous = true;
    cfg.use_group_buffer = true;
    hammer(cfg, 400);
}

/// Death/rejoin chaos: a dedicated chaos thread repeatedly kills and
/// rejoins one victim rank while every other rank hammers the
/// coordinator. The victim can still be drafted into groups while alive,
/// so purges race live arms and completes. Afterwards: all purges
/// accounted, no lock bit on any dead rank at any observed point, no
/// leaks after drain.
#[test]
fn stress_death_rejoin_chaos_purges_completely() {
    let n = 16usize;
    let victim = n - 1;
    let rounds = 60u64;
    let gg = Arc::new(ShardedGg::new(GgConfig::smart(n, 4, 3, 8), 0xC4A0));
    let stop = Arc::new(AtomicBool::new(false));

    std::thread::scope(|scope| {
        // workers: every rank except the victim
        for w in 0..n - 1 {
            let gg = Arc::clone(&gg);
            let stop = Arc::clone(&stop);
            scope.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let (_, newly) = gg.request(w);
                    let mut todo: Vec<_> = newly.into_iter().map(|g| g.id).collect();
                    while let Some(id) = todo.pop() {
                        // purged groups complete as no-ops (Unknown)
                        todo.extend(gg.complete(id).into_iter().map(|g| g.id));
                    }
                }
            });
        }
        // chaos: death + rejoin of the victim, owning its rank exclusively
        let chaos_gg = Arc::clone(&gg);
        let chaos_stop = Arc::clone(&stop);
        scope.spawn(move || {
            for _ in 0..rounds {
                let purge = chaos_gg.declare_dead(victim);
                // the dead rank must hold no lock the instant the purge
                // returns (the purge's own guard sweep)
                assert!(
                    !chaos_gg.is_locked_worker(victim),
                    "dead victim still holds a lock"
                );
                assert!(chaos_gg.is_dead(victim));
                let mut todo: Vec<_> =
                    purge.newly_armed.into_iter().map(|g| g.id).collect();
                while let Some(id) = todo.pop() {
                    todo.extend(chaos_gg.complete(id).into_iter().map(|g| g.id));
                }
                chaos_gg.rejoin(victim);
            }
            chaos_stop.store(true, Ordering::Release);
        });
    });

    drain(&gg);
    assert_eq!(gg.pending_len(), 0, "pending groups leaked across purges");
    assert_eq!(gg.locked_count(), 0, "locks leaked across purges");
    let stats = gg.stats();
    // every chaos round is one death + one rejoin-revive; the rejoin's
    // internal purge only counts a death if the rank was still dead
    // (it never is here — the chaos thread is the only killer)
    assert_eq!(stats.deaths, rounds, "death count drifted");
    assert_eq!(stats.rejoins, rounds, "rejoin count drifted");
    assert!(!gg.is_dead(victim), "victim must end revived");
}

/// Scale e2e: 64 real localhost ranks, each its own thread + TCP
/// connection, against one reactor-served sharded `GgServer`. Every Sync
/// must be served exactly once; the armed-group chains drain exactly as
/// in the in-process hammer (each client completes what it owns, waits
/// for what it was assigned).
#[test]
fn scale_e2e_64_ranks_over_tcp() {
    use ripples::rpc::{GgClient, GgServer};

    let ranks = 64usize;
    let iters = 10usize;
    let server =
        GgServer::spawn("127.0.0.1:0", GgConfig::random(ranks, 4, 4), 21).unwrap();
    let addr = server.addr;
    let handles: Vec<_> = (0..ranks)
        .map(|w| {
            std::thread::spawn(move || {
                let mut c = GgClient::connect(addr).unwrap();
                // a deadlock must fail loudly, not hang the suite
                c.set_io_timeout(std::time::Duration::from_secs(60)).unwrap();
                for _ in 0..iters {
                    let (assigned, armed) = c.sync(w, 0.01).unwrap();
                    let mut todo: Vec<_> = armed.into_iter().map(|(g, _)| g).collect();
                    while let Some(gid) = todo.pop() {
                        for (ng, _) in c.complete(gid).unwrap() {
                            todo.push(ng);
                        }
                    }
                    if let Some((gid, _, _)) = assigned {
                        c.wait_done(gid).unwrap();
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let mut c = GgClient::connect(addr).unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(
        stats.requests,
        (ranks * iters) as u64,
        "every Sync must be served exactly once"
    );
    server.shutdown();
}
