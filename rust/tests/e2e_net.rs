//! End-to-end tests of the distributed TCP data plane: real worker
//! *processes* on localhost, group membership from the GG service, model
//! bytes over framed TCP ring collectives.
//!
//! These spawn the `ripples` binary itself (Cargo builds it for
//! integration tests and exports the path via `CARGO_BIN_EXE_ripples`).

use std::path::PathBuf;

use ripples::net::{launch_local, LaunchConfig};

fn bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_ripples"))
}

/// The acceptance scenario: a 4-process cluster with worker 0 slowed 3x.
/// Loss must decrease everywhere, groups must actually execute over TCP,
/// and the fast workers must not be gated down to the slow worker's rate
/// (the paper's core heterogeneity claim, here on real sockets).
#[test]
fn four_process_cluster_with_straggler() {
    let cfg = LaunchConfig {
        bin: bin(),
        workers: 4,
        slow: Some((0, 3.0)),
        secs: 4.0,
        group_size: 2,
        smart: true,
        c_thres: 2,
        compute_floor_ms: 8,
        seed: 42,
        ..LaunchConfig::default()
    };
    let report = launch_local(&cfg).expect("cluster run");
    assert_eq!(report.workers.len(), 4);

    let (requests, _conflicts, created, _hits) = report.gg_stats;
    assert!(requests > 0, "workers never reached the GG");
    assert!(created > 0, "GG never created a group");

    for w in &report.workers {
        assert!(
            w.preduces > 0,
            "worker {} never executed a P-Reduce over TCP: {w:?}",
            w.rank
        );
        assert!(
            w.loss_last < w.loss_first * 0.85,
            "worker {} loss did not decrease: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
    }

    // Heterogeneity: within the same wall-clock window the fast workers
    // must complete substantially more iterations than the 3x straggler.
    // Fully gated lockstep would put this ratio at ~1.0; the smart GG's
    // idle-only Global Division plus the slowdown filter keeps the fast
    // side free-running (ideal ratio ~3).
    let slow_iters = report.workers[0].iters as f64;
    let fast_mean = report.workers[1..]
        .iter()
        .map(|w| w.iters as f64)
        .sum::<f64>()
        / 3.0;
    assert!(
        fast_mean > 1.3 * slow_iters,
        "fast workers gated by the straggler: fast mean {fast_mean:.0} vs slow {slow_iters:.0}"
    );
}

/// Random-GG pair: the minimal cluster exercises the non-smart scheduling
/// path and the leader/`WaitDone` completion protocol.
#[test]
fn two_process_random_gg_pair() {
    let cfg = LaunchConfig {
        bin: bin(),
        workers: 2,
        slow: None,
        secs: 1.5,
        group_size: 2,
        smart: false,
        compute_floor_ms: 2,
        seed: 7,
        ..LaunchConfig::default()
    };
    let report = launch_local(&cfg).expect("pair run");
    assert_eq!(report.workers.len(), 2);
    for w in &report.workers {
        assert!(w.iters > 0);
        assert!(w.preduces > 0, "pair never synchronized: {w:?}");
        assert!(
            w.loss_last < w.loss_first,
            "worker {} loss did not decrease: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
    }
}
