//! End-to-end tests of the distributed TCP data plane: real worker
//! *processes* on localhost, group membership from the GG service, model
//! bytes over framed TCP ring collectives.
//!
//! These spawn the `ripples` binary itself (Cargo builds it for
//! integration tests and exports the path via `CARGO_BIN_EXE_ripples`).

use std::path::PathBuf;
use std::time::Duration;

use ripples::cluster::SlowdownEvent;
use ripples::collectives::{OverlapConfig, WireCodec};
use ripples::net::{launch_local, KillSpec, LaunchConfig, LaunchReport};

fn bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_ripples"))
}

/// Hard test timeout: a fault-tolerance regression must FAIL the test,
/// not hang CI. The work runs on a helper thread; if it outlives the
/// deadline the test panics (the thread is leaked — the process is about
/// to die anyway).
fn with_timeout<T, F>(secs: u64, what: &str, f: F) -> T
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(Duration::from_secs(secs))
        .unwrap_or_else(|_| panic!("{what}: hung past the {secs}s test timeout"))
}

/// The acceptance scenario: a 4-process cluster with worker 0 slowed 3x.
/// Loss must decrease everywhere, groups must actually execute over TCP,
/// and the fast workers must not be gated down to the slow worker's rate
/// (the paper's core heterogeneity claim, here on real sockets).
#[test]
fn four_process_cluster_with_straggler() {
    let cfg = LaunchConfig {
        bin: bin(),
        workers: 4,
        slow: Some((0, 3.0)),
        secs: 4.0,
        group_size: 2,
        smart: true,
        c_thres: 2,
        compute_floor_ms: 8,
        seed: 42,
        ..LaunchConfig::default()
    };
    let report = launch_local(&cfg).expect("cluster run");
    assert_eq!(report.workers.len(), 4);

    assert!(report.gg_stats.requests > 0, "workers never reached the GG");
    assert!(report.gg_stats.groups_created > 0, "GG never created a group");
    // every worker piggybacked speed telemetry on its Sync RPCs
    assert!(
        report.gg_stats.speeds.iter().all(|&v| v > 0.0),
        "missing speed reports: {:?}",
        report.gg_stats.speeds
    );

    for w in &report.workers {
        assert!(
            w.preduces > 0,
            "worker {} never executed a P-Reduce over TCP: {w:?}",
            w.rank
        );
        assert!(
            w.loss_last < w.loss_first * 0.85,
            "worker {} loss did not decrease: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
    }

    // Heterogeneity: within the same wall-clock window the fast workers
    // must complete substantially more iterations than the 3x straggler.
    // Fully gated lockstep would put this ratio at ~1.0; the smart GG's
    // idle-only Global Division plus the slowdown filter keeps the fast
    // side free-running (ideal ratio ~3).
    let slow_iters = report.workers[0].iters as f64;
    let fast_mean = report.workers[1..]
        .iter()
        .map(|w| w.iters as f64)
        .sum::<f64>()
        / 3.0;
    assert!(
        fast_mean > 1.3 * slow_iters,
        "fast workers gated by the straggler: fast mean {fast_mean:.0} vs slow {slow_iters:.0}"
    );
}

/// The compressed-wire acceptance scenario: the 4-process cluster runs
/// end to end under `--wire fp16` and `--wire q8`. Every worker must
/// train (loss decreases through lossy collectives), execute P-Reduces,
/// and meter its data-plane bytes — and q8 must ship measurably fewer
/// bytes per collective than fp16 (the whole point of the codec).
#[test]
fn four_process_cluster_under_compressed_wire() {
    let base = LaunchConfig {
        bin: bin(),
        workers: 4,
        slow: Some((0, 3.0)),
        secs: 3.0,
        group_size: 2,
        smart: true,
        c_thres: 2,
        compute_floor_ms: 8,
        seed: 42,
        ..LaunchConfig::default()
    };
    let mut tx_per_preduce = Vec::new();
    for wire in [WireCodec::Fp16, WireCodec::Q8] {
        let report = launch_local(&LaunchConfig { wire, ..base.clone() })
            .unwrap_or_else(|e| panic!("{wire} cluster run: {e:#}"));
        assert_eq!(report.workers.len(), 4, "{wire}");
        let (mut tx, mut preduces) = (0u64, 0u64);
        for w in &report.workers {
            assert!(w.preduces > 0, "{wire}: worker {} never synchronized: {w:?}", w.rank);
            assert!(
                w.loss_last < w.loss_first * 0.85,
                "{wire}: worker {} loss did not decrease: {} -> {}",
                w.rank,
                w.loss_first,
                w.loss_last
            );
            assert!(w.bytes_tx > 0, "{wire}: worker {} metered no tx bytes", w.rank);
            assert!(w.bytes_rx > 0, "{wire}: worker {} metered no rx bytes", w.rank);
            tx += w.bytes_tx;
            preduces += w.preduces;
        }
        tx_per_preduce.push(tx as f64 / preduces as f64);
    }
    // q8 chunks are ~half the bytes of fp16 chunks (1 vs 2 bytes/elem,
    // plus small fixed headers) — visible per collective on the meter
    let (fp16, q8) = (tx_per_preduce[0], tx_per_preduce[1]);
    assert!(
        q8 < 0.75 * fp16,
        "q8 did not compress vs fp16: {q8:.0} vs {fp16:.0} tx bytes/preduce"
    );
}

/// The dynamic-straggler acceptance scenario: worker 0 becomes 3x slow
/// *mid-run* via `--slow-schedule` (no configured slowdown reaches the
/// GG — only the piggybacked measurements). Asserted from each run's
/// own metrics:
///  * the GG speed table converges to the true factor within 30%;
///  * smart mode stops drafting the straggler within a bounded number
///    of requests (none in the final stretch of the run);
///  * random mode (filter off) keeps drafting it to the end.
#[test]
fn dynamic_straggler_filter_reaction() {
    let base = LaunchConfig {
        bin: bin(),
        workers: 4,
        slow: None,
        slow_schedule: vec![SlowdownEvent { worker: 0, factor: 3.0, start_iter: 40 }],
        secs: 4.0,
        group_size: 2,
        c_thres: 2,
        compute_floor_ms: 8,
        seed: 42,
        ..LaunchConfig::default()
    };
    // requests with no straggler draft that count as "stopped drafting";
    // a 4-worker cluster at an 8ms floor serves hundreds of requests in
    // the window, so 40 is bounded but far above scheduling noise
    const BOUND: u64 = 40;

    let smart = launch_local(&LaunchConfig { smart: true, ..base.clone() })
        .expect("smart cluster run");
    let s = &smart.gg_stats;
    let rel = s.relative_speed(0).expect("straggler never reported a speed");
    assert!(
        (rel - 3.0).abs() < 0.3 * 3.0,
        "speed table did not converge: measured {rel:.2} vs true 3.0 (ewma {:?})",
        s.speeds
    );
    for w in 1..4 {
        let r = s.relative_speed(w).expect("fast worker never reported");
        assert!(r < 2.0, "fast worker {w} mis-measured at {r:.2}");
    }
    assert!(s.drafts[0] > 0, "straggler was never drafted before the onset");
    assert!(
        s.requests - s.last_drafted[0] >= BOUND,
        "smart GG kept drafting the straggler: last draft at request {} of {}",
        s.last_drafted[0],
        s.requests
    );

    let random = launch_local(&LaunchConfig { smart: false, ..base })
        .expect("random cluster run");
    let r = &random.gg_stats;
    assert!(r.drafts[0] > 0, "random GG never drafted the straggler at all");
    assert!(
        r.requests - r.last_drafted[0] < BOUND,
        "random GG (filter off) should keep drafting the straggler: \
         last draft at request {} of {}",
        r.last_drafted[0],
        r.requests
    );
}

/// The overlap acceptance scenario: the same 4-process cluster run twice,
/// serially and with the pipelined P-Reduce (K=4 shards, staleness 6).
/// The overlapped run must (a) actually take stale steps, (b) spend
/// strictly less wall-clock blocked on synchronization, and (c) reach a
/// final loss within tolerance of the serial run — overlap buys wait
/// time, not convergence.
#[test]
fn overlap_pipeline_reduces_exposed_sync() {
    let base = LaunchConfig {
        bin: bin(),
        workers: 4,
        // a 3x straggler creates real rendezvous wait for overlap to hide
        slow: Some((0, 3.0)),
        secs: 4.0,
        group_size: 2,
        smart: true,
        c_thres: 2,
        compute_floor_ms: 8,
        seed: 42,
        ..LaunchConfig::default()
    };
    let serial = launch_local(&base).expect("serial cluster run");
    let overlapped = launch_local(&LaunchConfig {
        overlap: OverlapConfig { shards: 4, max_staleness: 6 },
        ..base.clone()
    })
    .expect("overlapped cluster run");

    for w in &serial.workers {
        assert_eq!(w.stale_steps, 0, "serial mode must not stale-step: {w:?}");
    }
    let stale: u64 = overlapped.workers.iter().map(|w| w.stale_steps).sum();
    assert!(stale > 0, "overlap never hid any wait: {:?}", overlapped.workers);

    let blocked = |r: &LaunchReport| -> f64 {
        r.workers.iter().map(|w| w.sync_blocked_secs).sum()
    };
    assert!(
        blocked(&overlapped) < blocked(&serial),
        "exposed sync wait did not drop: overlap {:.3}s vs serial {:.3}s",
        blocked(&overlapped),
        blocked(&serial)
    );

    // equal-loss-trajectory tolerance: the overlapped run must train as
    // well as the serial one (both from the same init and data)
    let mean_loss = |r: &LaunchReport| -> f64 {
        r.workers.iter().map(|w| w.loss_last).sum::<f64>() / r.workers.len() as f64
    };
    for w in &overlapped.workers {
        assert!(
            w.loss_last < w.loss_first * 0.85,
            "worker {} loss did not decrease under overlap: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
    }
    let (ls, lo) = (mean_loss(&serial), mean_loss(&overlapped));
    assert!(
        (ls - lo).abs() < 0.5 * ls.max(lo) + 0.05,
        "final losses diverged: serial {ls:.4} vs overlap {lo:.4}"
    );
}

/// The staged-pipeline acceptance scenario (DESIGN.md §Perf): a
/// 4-process cluster with a deliberately slow loader (`--load-ms 20`
/// under a 40 ms compute floor). With `--prefetch 0` every step pays the
/// load serially and `load_wait` grows by ~20 ms per iteration; with
/// `--prefetch 4` the loader thread runs ahead of compute, so the only
/// exposed load is the first-batch priming. The staged run must show a
/// strictly lower `load_wait`, loader-side backpressure (`compute_wait`
/// > 0 exactly because the loader outpaces compute), and an equal loss
/// trajectory — same seed, same batch tags, only the overlap differs.
#[test]
fn pipeline_prefetch_hides_slow_loader() {
    let base = LaunchConfig {
        bin: bin(),
        workers: 4,
        secs: 4.0,
        group_size: 2,
        smart: true,
        c_thres: 2,
        compute_floor_ms: 40,
        load_floor_ms: 20,
        seed: 42,
        ..LaunchConfig::default()
    };
    let lockstep = launch_local(&base).expect("lockstep cluster run");
    let staged = launch_local(&LaunchConfig { prefetch: 4, ..base.clone() })
        .expect("staged cluster run");

    let load_wait = |r: &LaunchReport| -> f64 {
        r.workers.iter().map(|w| w.load_wait_secs).sum()
    };
    // sanity: the slow loader actually hurt the serial path (~20 ms/iter)
    assert!(
        load_wait(&lockstep) > 0.5,
        "lockstep run did not expose the load floor: {:.3}s",
        load_wait(&lockstep)
    );
    assert!(
        load_wait(&staged) < 0.25 * load_wait(&lockstep),
        "prefetch did not hide the slow loader: staged {:.3}s vs lockstep {:.3}s",
        load_wait(&staged),
        load_wait(&lockstep)
    );
    // without a loader thread there is nothing to backpressure ...
    for w in &lockstep.workers {
        assert_eq!(
            w.compute_wait_secs, 0.0,
            "lockstep worker {} reported loader backpressure: {w:?}",
            w.rank
        );
    }
    // ... while the staged loader (20 ms) outpaces compute (40 ms) and
    // spends the surplus blocked on the full batch queue
    assert!(
        staged.workers.iter().any(|w| w.compute_wait_secs > 0.0),
        "staged loaders never hit backpressure: {:?}",
        staged.workers
    );

    let mean_loss = |r: &LaunchReport| -> f64 {
        r.workers.iter().map(|w| w.loss_last).sum::<f64>() / r.workers.len() as f64
    };
    for w in &staged.workers {
        assert!(
            w.loss_last < w.loss_first * 0.85,
            "worker {} loss did not decrease under prefetch: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
    }
    let (ll, ls) = (mean_loss(&lockstep), mean_loss(&staged));
    assert!(
        (ll - ls).abs() < 0.5 * ll.max(ls) + 0.05,
        "final losses diverged: lockstep {ll:.4} vs staged {ls:.4}"
    );
}

/// The chaos acceptance scenario: a 4-process cluster, one worker
/// SIGKILLed mid-run (with an 8 ms compute floor and constant syncing,
/// that lands mid-collective or with in-flight group state). The
/// remaining workers must detect the crash (heartbeat liveness +
/// data-plane accusations), abort/repair the broken groups, finish the
/// timed window, and train about as well as a crash-free 3-worker
/// cluster — neither hanging nor crashing.
#[test]
fn chaos_kill_worker_mid_run_cluster_repairs_and_finishes() {
    let base = LaunchConfig {
        bin: bin(),
        workers: 4,
        secs: 3.0,
        group_size: 2,
        smart: true,
        c_thres: 2,
        compute_floor_ms: 8,
        seed: 42,
        liveness_ms: 2000,
        heartbeat_ms: 100,
        ..LaunchConfig::default()
    };
    let report = with_timeout(120, "chaos kill run", {
        let cfg = LaunchConfig {
            kill: Some(KillSpec { rank: 3, after_secs: 1.0, rejoin_after_secs: None }),
            ..base.clone()
        };
        move || launch_local(&cfg).expect("chaos cluster run")
    });
    assert_eq!(report.killed, Some(3));
    assert_eq!(report.workers.len(), 3, "exactly the survivors report");
    let s = &report.gg_stats;
    assert_eq!(s.deaths, 1, "the killed rank must be declared dead (and only it)");
    assert_eq!(s.rejoins, 0);
    // the cluster kept scheduling after the kill
    let at_kill = report.gg_stats_at_kill.as_ref().expect("kill snapshot");
    assert!(
        s.requests > at_kill.requests + 10,
        "survivors stopped syncing after the kill: {} -> {}",
        at_kill.requests,
        s.requests
    );
    for w in &report.workers {
        assert_ne!(w.rank, 3);
        assert!(w.preduces > 0, "survivor {} never synchronized: {w:?}", w.rank);
        assert!(
            w.loss_last < w.loss_first * 0.85,
            "survivor {} loss did not decrease: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
    }

    // crash-free 3-worker reference: the repaired cluster must train to a
    // comparable loss (same seed, same window — the dead rank's absence
    // is the only difference after repair)
    let reference = with_timeout(120, "crash-free reference run", {
        let cfg = LaunchConfig { workers: 3, ..base };
        move || launch_local(&cfg).expect("reference cluster run")
    });
    let mean_loss = |r: &LaunchReport| -> f64 {
        r.workers.iter().map(|w| w.loss_last).sum::<f64>() / r.workers.len() as f64
    };
    let (lc, lr) = (mean_loss(&report), mean_loss(&reference));
    assert!(
        (lc - lr).abs() < 0.5 * lc.max(lr) + 0.05,
        "repaired cluster trained much worse than crash-free: {lc:.4} vs {lr:.4}"
    );
}

/// Abort-parity regression for the shared `collective_attempt` helper:
/// the serial and overlapped paths now snapshot/rollback/retry through
/// the same code, so a mid-collective kill must behave identically on
/// each. Run the same kill scenario serial and overlapped (K=4, S=6):
/// both clusters must abort at least one in-flight collective, declare
/// exactly the killed rank dead, finish the window with every survivor
/// training, and land on equal final losses within tolerance.
#[test]
fn chaos_abort_parity_serial_vs_overlapped() {
    let base = LaunchConfig {
        bin: bin(),
        workers: 4,
        secs: 3.0,
        group_size: 2,
        smart: true,
        c_thres: 2,
        compute_floor_ms: 8,
        seed: 42,
        liveness_ms: 2000,
        heartbeat_ms: 100,
        kill: Some(KillSpec { rank: 3, after_secs: 1.0, rejoin_after_secs: None }),
        ..LaunchConfig::default()
    };
    let serial = with_timeout(120, "serial abort-parity run", {
        let cfg = base.clone();
        move || launch_local(&cfg).expect("serial chaos run")
    });
    let overlapped = with_timeout(120, "overlapped abort-parity run", {
        let cfg = LaunchConfig {
            overlap: OverlapConfig { shards: 4, max_staleness: 6 },
            ..base
        };
        move || launch_local(&cfg).expect("overlapped chaos run")
    });

    for (label, report) in [("serial", &serial), ("overlapped", &overlapped)] {
        assert_eq!(report.killed, Some(3), "{label}: kill was not delivered");
        assert_eq!(report.workers.len(), 3, "{label}: exactly the survivors report");
        assert_eq!(
            report.gg_stats.deaths, 1,
            "{label}: the killed rank must be declared dead (and only it)"
        );
        // the kill must have interrupted real in-flight collectives —
        // the snapshot/rollback path under test actually ran
        let aborts: u64 = report.workers.iter().map(|w| w.aborts).sum();
        assert!(
            aborts > 0,
            "{label}: no survivor aborted a collective around the kill: {:?}",
            report.workers
        );
        for w in &report.workers {
            assert_ne!(w.rank, 3);
            assert!(w.preduces > 0, "{label}: survivor {} never synchronized: {w:?}", w.rank);
            assert!(
                w.loss_last < w.loss_first * 0.85,
                "{label}: survivor {} loss did not decrease after rollback: {} -> {}",
                w.rank,
                w.loss_first,
                w.loss_last
            );
        }
    }

    // parity: the rollback-and-carry-on outcome must not depend on which
    // execution path (serial vs overlapped) hit the abort
    let mean_loss = |r: &LaunchReport| -> f64 {
        r.workers.iter().map(|w| w.loss_last).sum::<f64>() / r.workers.len() as f64
    };
    let (ls, lo) = (mean_loss(&serial), mean_loss(&overlapped));
    assert!(
        (ls - lo).abs() < 0.5 * ls.max(lo) + 0.05,
        "abort handling diverged across paths: serial {ls:.4} vs overlapped {lo:.4}"
    );
}

/// Chaos × compression: one kill-mid-run case under `--wire q8` — the
/// poison/abort/repair paths must survive compressed frames (stale-frame
/// skipping and poison relay key off the frame *tag*, which every codec
/// variant carries). The cluster must repair, finish, and keep training.
#[test]
fn chaos_kill_worker_mid_run_under_q8_wire() {
    let cfg = LaunchConfig {
        bin: bin(),
        workers: 4,
        secs: 3.0,
        group_size: 2,
        smart: true,
        c_thres: 2,
        compute_floor_ms: 8,
        seed: 44,
        liveness_ms: 2000,
        heartbeat_ms: 100,
        wire: WireCodec::Q8,
        kill: Some(KillSpec { rank: 3, after_secs: 1.0, rejoin_after_secs: None }),
        ..LaunchConfig::default()
    };
    let report = with_timeout(120, "chaos q8 kill run", move || {
        launch_local(&cfg).expect("chaos q8 cluster run")
    });
    assert_eq!(report.killed, Some(3));
    assert_eq!(report.workers.len(), 3, "exactly the survivors report");
    assert_eq!(report.gg_stats.deaths, 1, "the killed rank must be declared dead");
    for w in &report.workers {
        assert_ne!(w.rank, 3);
        assert!(w.preduces > 0, "survivor {} never synchronized: {w:?}", w.rank);
        assert!(w.bytes_tx > 0, "survivor {} metered no compressed bytes", w.rank);
        assert!(
            w.loss_last < w.loss_first * 0.85,
            "survivor {} loss did not decrease under q8: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
    }
}

/// The rejoin acceptance scenario: kill a worker, then spawn a
/// replacement that restores the freshest shared checkpoint and rejoins
/// under the same rank at a *new* data-plane address. The replacement
/// must train and be drafted by other initiators again (asserted via the
/// GG's `StatsReport` draft counters against the at-kill snapshot).
#[test]
fn chaos_rejoin_restores_from_checkpoint_and_contributes() {
    let ckpt_dir = std::env::temp_dir()
        .join(format!("ripples_chaos_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let cfg = LaunchConfig {
        bin: bin(),
        workers: 4,
        secs: 4.0,
        group_size: 2,
        smart: true,
        c_thres: 2,
        compute_floor_ms: 8,
        seed: 43,
        liveness_ms: 2000,
        heartbeat_ms: 100,
        ckpt_every: 5,
        ckpt_dir: Some(ckpt_dir.clone()),
        kill: Some(KillSpec { rank: 3, after_secs: 1.2, rejoin_after_secs: Some(0.8) }),
        ..LaunchConfig::default()
    };
    let report = with_timeout(150, "chaos rejoin run", move || {
        launch_local(&cfg).expect("rejoin cluster run")
    });
    assert_eq!(report.killed, Some(3));
    let s = &report.gg_stats;
    assert_eq!(s.deaths, 1);
    assert_eq!(s.rejoins, 1, "the replacement must have rejoined");
    // all four ranks report: 3 survivors + the replacement under rank 3
    assert_eq!(report.workers.len(), 4);
    let replacement = report
        .workers
        .iter()
        .find(|w| w.rank == 3)
        .expect("replacement must report under the killed rank");
    assert!(replacement.iters > 0, "replacement never trained: {replacement:?}");
    assert!(
        replacement.preduces > 0,
        "replacement never executed a P-Reduce: {replacement:?}"
    );
    // drafted AGAIN: its most recent draft by another initiator happened
    // after the kill-time request counter
    let at_kill = report.gg_stats_at_kill.as_ref().expect("kill snapshot");
    assert!(
        s.last_drafted[3] > at_kill.requests,
        "restored rank was never drafted post-rejoin: last draft at request {} \
         vs {} requests at kill",
        s.last_drafted[3],
        at_kill.requests
    );
    // checkpoints were actually written (the replacement restored one)
    assert!(
        std::fs::read_dir(&ckpt_dir).map(|d| d.count() > 0).unwrap_or(false),
        "no checkpoints in {}",
        ckpt_dir.display()
    );
    let _ = std::fs::remove_dir_all(&ckpt_dir);
}

/// The topology acceptance scenario: 4 processes declared as 2 machines
/// of 2 (`--topo m0:0,1;m1:2,3`), group size 3 so every drafted group
/// spans machines with at least one multi-member node — the GG must ship
/// multi-node plans and the workers must run the two-level hierarchical
/// collective (intra gather -> leader ring -> broadcast) over real
/// sockets, and still train.
#[test]
fn topo_four_process_hierarchical_collective() {
    let cfg = LaunchConfig {
        bin: bin(),
        workers: 4,
        secs: 3.0,
        group_size: 3,
        smart: true,
        c_thres: 2,
        compute_floor_ms: 8,
        seed: 42,
        topo: Some("m0:0,1;m1:2,3".into()),
        ..LaunchConfig::default()
    };
    let report = with_timeout(120, "topo cluster run", move || {
        launch_local(&cfg).expect("topo cluster run")
    });
    assert_eq!(report.workers.len(), 4);
    assert!(report.gg_stats.groups_created > 0, "GG never created a group");
    for w in &report.workers {
        assert!(w.preduces > 0, "worker {} never synchronized: {w:?}", w.rank);
        assert!(
            w.loss_last < w.loss_first * 0.85,
            "worker {} loss did not decrease through hierarchical collectives: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
    }
    // the point of the scenario: two-level collectives actually executed
    // (size-3 groups over a 2+2 placement are always multi-node)
    let hier: u64 = report.workers.iter().map(|w| w.hier_preduces).sum();
    assert!(
        hier > 0,
        "no group ever ran the two-level collective: {:?}",
        report.workers
    );
}

/// Topology × chaos: kill a rank mid-run while the cluster is executing
/// two-level collectives. A death inside the hierarchy must unwind both
/// levels — member<->leader edges and the leader ring — via the poison
/// relay + GG abort, and the repaired 3-worker cluster (still spanning
/// both declared machines) must keep running hierarchical groups and
/// finish training.
#[test]
fn chaos_topo_kill_mid_hier_collective_unwinds_both_levels() {
    let cfg = LaunchConfig {
        bin: bin(),
        workers: 4,
        secs: 3.0,
        group_size: 3,
        smart: true,
        c_thres: 2,
        compute_floor_ms: 8,
        seed: 42,
        liveness_ms: 2000,
        heartbeat_ms: 100,
        topo: Some("m0:0,1;m1:2,3".into()),
        kill: Some(KillSpec { rank: 3, after_secs: 1.0, rejoin_after_secs: None }),
        ..LaunchConfig::default()
    };
    let report = with_timeout(120, "topo chaos run", move || {
        launch_local(&cfg).expect("topo chaos cluster run")
    });
    assert_eq!(report.killed, Some(3));
    assert_eq!(report.workers.len(), 3, "exactly the survivors report");
    assert_eq!(
        report.gg_stats.deaths, 1,
        "the killed rank must be declared dead (and only it)"
    );
    // the kill must have interrupted in-flight collectives: survivors
    // unwound (poison relay across one or both levels) and retried
    let aborts: u64 = report.workers.iter().map(|w| w.aborts).sum();
    assert!(
        aborts > 0,
        "no survivor aborted a collective around the kill: {:?}",
        report.workers
    );
    let mut hier = 0u64;
    for w in &report.workers {
        assert_ne!(w.rank, 3);
        assert!(w.preduces > 0, "survivor {} never synchronized: {w:?}", w.rank);
        assert!(
            w.loss_last < w.loss_first * 0.85,
            "survivor {} loss did not decrease after unwind: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
        hier += w.hier_preduces;
    }
    // the repaired cluster still spans both machines (size-3 groups over
    // m0:{0,1} + m1:{2}), so two-level collectives kept completing
    assert!(
        hier > 0,
        "no two-level collective completed across the kill: {:?}",
        report.workers
    );
}

/// Random-GG pair: the minimal cluster exercises the non-smart scheduling
/// path and the leader/`WaitDone` completion protocol.
#[test]
fn two_process_random_gg_pair() {
    let cfg = LaunchConfig {
        bin: bin(),
        workers: 2,
        slow: None,
        secs: 1.5,
        group_size: 2,
        smart: false,
        compute_floor_ms: 2,
        seed: 7,
        ..LaunchConfig::default()
    };
    let report = launch_local(&cfg).expect("pair run");
    assert_eq!(report.workers.len(), 2);
    for w in &report.workers {
        assert!(w.iters > 0);
        assert!(w.preduces > 0, "pair never synchronized: {w:?}");
        assert!(
            w.loss_last < w.loss_first,
            "worker {} loss did not decrease: {} -> {}",
            w.rank,
            w.loss_first,
            w.loss_last
        );
    }
}
