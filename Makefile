# Ripples build/verify entry points. `make verify` is the full gate a PR
# must pass; `cargo build --release && cargo test -q` alone is tier-1.

CARGO ?= cargo
PYTHON ?= python3
ARTIFACTS_DIR ?= artifacts

.PHONY: build test chaos e2e pipeline stress topo modelcheck lint-strict tsan miri clippy doc fmt verify artifacts python-test bench bench-json paper clean

build:
	$(CARGO) build --release

test: build
	$(CARGO) test -q

# Chaos gate, explicitly: the fault-injection e2e suite (kill a worker
# mid-collective; repair + checkpoint-rejoin; one kill-mid-run case
# under `--wire q8` proving poison/abort paths survive compressed
# frames). Included in `cargo test` too — this target exists so
# `verify` names the crash path even when test filters change.
chaos:
	$(CARGO) test -q --test e2e_net chaos_

# End-to-end data-plane gate: the Ripples collectives suite plus the
# AD-PSGD / Parameter Server baseline suite (`--algo adpsgd|ps`) — real
# multi-process TCP clusters, the real-socket counterpart of `fig paper`.
# Included in `cargo test` too; named here like `chaos` so `verify`
# spells the gate out even when test filters change.
e2e:
	$(CARGO) test -q --test e2e_net --test e2e_baselines

# Concurrency stress gate: many real threads hammer one sharded Group
# Generator (plus a 64-rank TCP e2e against the reactor) asserting the
# paper's serialization invariants — no double grants, GB FIFO,
# complete death purges, no leaked locks. Runs single-threaded per test
# binary so each case owns all cores, under a hard wall-clock cap: the
# suite's loops are bounded and its sockets carry IO timeouts, so a
# deadlock fails the build instead of wedging it.
stress:
	timeout 600 $(CARGO) test -q --release --test stress_gg -- --test-threads=1

# Staged step-pipeline gate (DESIGN.md §Perf): the `step` module's
# bounded-queue/stage unit tests, the staged sim time model (bitwise
# determinism + zero-load identity), the seeded queue property suite,
# and the 4-process prefetch e2e. Included in `cargo test` too — named
# here so `verify` spells the gate out even when test filters change.
pipeline:
	$(CARGO) test -q step::
	$(CARGO) test -q staged_
	$(CARGO) test -q --test prop_net --test e2e_net pipeline_

# Topology gate (DESIGN.md §Perf "Hierarchical P-Reduce"): `--topo`
# parsing + plan assembly (bit-identical across GG backends), the
# two-level collective unit tests, the shared-uplink/hierarchical cost
# model, the fig-topo shape claims (live and against the committed
# results/BENCH_topo.json), and the 4-process hierarchical e2e with its
# mid-collective kill variant. Included in `cargo test` too — named
# here so `verify` spells the gate out even when test filters change.
topo:
	$(CARGO) test -q topo
	$(CARGO) test -q hier
	$(CARGO) test -q --test e2e_net topo_

# Model-check gate (DESIGN.md §Correctness): the modelcheck integration
# suite (committed minimized counterexample fixtures replayed against
# both real GG backends + the RPC seam, the shared ABORTED_SET_CAP pin,
# CHECK_gg.json shape), then the exhaustive bounded exploration itself —
# every scenario at 3 ranks to depth 20 with the sleep-set reduction
# measured — regenerating results/CHECK_gg.json. Any invariant violation
# fails the build and prints a minimized counterexample schedule.
modelcheck: build
	$(CARGO) test -q --test modelcheck
	$(CARGO) run --release -- check --ranks 3 --depth 20 --scenario all --json results/CHECK_gg.json

# Strict lint gate beyond clippy: no unwrap/expect in non-test net/rpc
# code (allowlist: tools/lint_allow.txt, stale entries fail) and the RPC
# frame-tag table must be a complete bijection with every Request
# variant dispatched. Pure-stdlib python3, no extra deps.
lint-strict:
	$(PYTHON) tools/lint_strict.py

verify: build test chaos e2e pipeline stress topo modelcheck lint-strict clippy doc fmt

# ThreadSanitizer gate (environment-gated; see EXPERIMENTS.md
# §Environment-gated tests): re-runs the concurrency stress suite and
# the step:: bounded-queue tests under TSan. Needs a nightly toolchain
# with the rust-src component (-Zbuild-std instruments std too); when no
# nightly is installed the target SKIPs with a notice instead of
# failing, so `make tsan` is safe to call anywhere.
tsan:
	@if $(CARGO) +nightly --version >/dev/null 2>&1; then \
		target=$$(rustc -vV | sed -n 's/^host: //p'); \
		RUSTFLAGS="-Zsanitizer=thread" $(CARGO) +nightly test -q \
			-Zbuild-std --target $$target --test stress_gg -- --test-threads=1 && \
		RUSTFLAGS="-Zsanitizer=thread" $(CARGO) +nightly test -q \
			-Zbuild-std --target $$target step:: ; \
	else \
		echo "tsan: SKIP — no nightly toolchain installed" \
			"(EXPERIMENTS.md §Environment-gated tests)"; \
	fi

# Miri gate (environment-gated, same doc section): interprets the step::
# bounded-queue/stage unit tests for undefined behaviour. stress_gg is
# excluded here on purpose — it opens real TCP sockets, which Miri
# cannot emulate (TSan covers it above). SKIPs with a notice when
# cargo-miri is not installed.
miri:
	@if $(CARGO) +nightly miri --version >/dev/null 2>&1; then \
		$(CARGO) +nightly miri test -q step:: ; \
	else \
		echo "miri: SKIP — cargo-miri not installed" \
			"(EXPERIMENTS.md §Environment-gated tests)"; \
	fi

# Lint gate: clippy over every target (lib, bin, tests, benches,
# examples) with warnings denied.
clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Documentation gate: rustdoc warnings (broken intra-doc links and
# friends) are errors, and doc examples must pass — keeps references
# like the DESIGN.md sections cited from source comments from rotting.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps
	$(CARGO) test --doc -q

# Formatting gate: the tree must be rustfmt-clean.
fmt:
	$(CARGO) fmt --check

# Lower the Layer-2/Layer-1 JAX graphs to HLO-text artifacts (needs
# Python + JAX; content-hashed, so re-running is a no-op when the
# graphs are unchanged). The PJRT runtime then needs `--features pjrt`.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

python-test:
	cd python && $(PYTHON) -m pytest tests -q

bench:
	$(CARGO) bench --bench bench_primitives
	$(CARGO) bench --bench bench_figures

# Machine-readable perf trajectory: every figure harness as
# results/BENCH_<id>.json (accumulated across PRs; see EXPERIMENTS.md).
# `fig all` includes `fig wire` (BENCH_wire.json: codec x bandwidth),
# `fig overlap` (BENCH_overlap.json: sharded-overlap + staged-pipeline
# axes; shape-asserted by figures::tests once generated), and
# `fig topo` (BENCH_topo.json: hierarchical vs flat placement;
# committed and shape-asserted by figures::tests).
bench-json: build
	$(CARGO) run --release -- fig all --json results

# The paper table: all four algorithms x {homogeneous, 5x straggler,
# 16x bandwidth cut} at one target loss -> results/BENCH_paper.json
# (committed; shape-asserted by bench::figures::tests::paper_table_shape).
paper: build
	$(CARGO) run --release -- fig paper --json results

clean:
	$(CARGO) clean
	rm -rf $(ARTIFACTS_DIR) results
