"""Build-time Python: Layer-1 Pallas kernels + Layer-2 JAX graphs + AOT.

Never imported by the Rust runtime; `make artifacts` runs `compile.aot`
once and the training path is pure Rust + PJRT afterwards.
"""
