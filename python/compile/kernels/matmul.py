"""Pallas tiled matmul kernel (Layer 1).

The models' compute hot-spot. On a real TPU this is MXU work: blocks are
multiples of the 128x128 systolic array, accumulation in float32, operands
ideally bfloat16. The BlockSpec grid expresses the HBM->VMEM schedule that a
CUDA kernel would express with threadblocks: grid = (M/bm, N/bn, K/bk), with
the K axis innermost so each (i, j) output tile is revisited across K steps
and accumulated in place (Pallas keeps the revisited block resident in VMEM).

interpret=True on this testbed; structure (not wallclock) is the deliverable.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(a_ref, b_ref, out_ref, *, k_steps):
    """One (bm, bk) @ (bk, bn) MAC accumulated into the (bm, bn) out tile.

    The out BlockSpec index map ignores k, so the same VMEM tile is
    revisited for all k steps — init at k == 0, accumulate after.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _ceil_to(x, m):
    return (x + m - 1) // m * m


def _matmul_impl(a, b, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """C = A @ B (float32 out) with MXU-shaped tiling; ragged dims padded."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n].astype(a.dtype)


@jax.custom_vjp
def matmul(a, b):
    """Differentiable Pallas matmul: C = A @ B.

    Pallas kernels have no automatic JVP rule, so the VJP is supplied
    explicitly — both cotangent products are themselves Pallas matmuls,
    keeping backward passes on the MXU-tiled path too:
        dA = g @ B^T ,  dB = A^T @ g
    """
    return _matmul_impl(a, b)


def _matmul_fwd(a, b):
    return _matmul_impl(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    return _matmul_impl(g, b.T), _matmul_impl(a.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint_bytes(bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK, dtype_bytes=4):
    """VMEM bytes resident per grid step: A tile + B tile + out tile."""
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes


def mxu_utilization_estimate(m, n, k, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Fraction of MXU issue slots doing useful MACs (pad waste only).

    Reported in DESIGN.md §Perf. Real utilization additionally depends on
    DMA overlap, which BlockSpec double-buffers automatically on TPU.
    """
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    return (m * n * k) / float(mp * np_ * kp)
