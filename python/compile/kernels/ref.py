"""Pure-jnp correctness oracles for the Pallas kernels (Layer 1).

Every kernel in this package has a reference implementation here; pytest
asserts allclose between the Pallas (interpret=True) kernel and its oracle
over a hypothesis-driven sweep of shapes and dtypes.
"""

import jax.numpy as jnp


def preduce_mean(stacked):
    """Reference for the P-Reduce reduction: mean over the group axis.

    ``stacked`` has shape ``(G, N)`` — G model replicas of a flattened
    parameter vector. The result is the averaged replica, shape ``(N,)``.
    """
    return jnp.mean(stacked, axis=0)


def preduce_weighted(stacked, weights):
    """Weighted P-Reduce: convex combination of replicas.

    ``weights`` has shape ``(G,)`` and should sum to 1 (a doubly-stochastic
    row of the fused synchronization matrix F^G).
    """
    return jnp.tensordot(weights, stacked, axes=1)


def matmul(a, b):
    """Reference for the tiled matmul kernel (float32 accumulation)."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def sgd_update(param, grad, lr):
    """Reference for the fused SGD update kernel."""
    return param - lr * grad


def momentum_update(param, grad, velocity, lr, momentum, weight_decay):
    """Reference for the fused momentum (heavy-ball) update kernel.

    Matches the paper's ResNet-50 setup: momentum=0.9, weight_decay=1e-4.
    v <- m*v + (g + wd*p) ; p <- p - lr*v
    """
    g = grad + weight_decay * param
    new_v = momentum * velocity + g
    new_p = param - lr * new_v
    return new_p, new_v
