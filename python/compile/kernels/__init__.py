"""Layer-1 Pallas kernels and their pure-jnp oracles.

Kernels (all interpret=True on this CPU testbed; see DESIGN.md
§Hardware-Adaptation for the TPU mapping):

* :mod:`.preduce`  — group-mean / weighted-mean reduction, the arithmetic
  core of the paper's Partial All-Reduce primitive.
* :mod:`.matmul`   — MXU-tiled matmul used by the Layer-2 models.
* :mod:`.sgd`      — fused SGD / momentum parameter updates over the
  paper's flat concatenated weight buffer (§6.1).
* :mod:`.ref`      — the oracles pytest checks everything against.
"""

from . import matmul, preduce, ref, sgd  # noqa: F401
