"""Pallas fused optimizer-update kernels (Layer 1).

The per-iteration parameter update is pure bandwidth: read param + grad
(+ velocity), write param (+ velocity). Fusing it into one kernel means one
pass over HBM instead of the 3-4 passes an unfused jnp expression can cost
before XLA fusion kicks in, and it guarantees the paper's flat-buffer
layout (all weights concatenated into one vector, §6.1) stays flat.

Tiles are 1-D ``block_n`` stripes, same VMEM reasoning as preduce.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 16384


def _sgd_kernel(p_ref, g_ref, lr_ref, out_ref):
    out_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


def _momentum_kernel(p_ref, g_ref, v_ref, h_ref, new_p_ref, new_v_ref):
    """h = [lr, momentum, weight_decay]; heavy-ball with decoupled wd term."""
    lr, mom, wd = h_ref[0], h_ref[1], h_ref[2]
    g = g_ref[...] + wd * p_ref[...]
    new_v = mom * v_ref[...] + g
    new_v_ref[...] = new_v
    new_p_ref[...] = p_ref[...] - lr * new_v


def _pad1(x, block_n):
    n = x.shape[0]
    rem = n % block_n
    if rem == 0:
        return x, n
    return jnp.pad(x, (0, block_n - rem)), n


@functools.partial(jax.jit, static_argnames=("block_n",))
def sgd_update(param, grad, lr, block_n=DEFAULT_BLOCK_N):
    """p <- p - lr*g over a flat (N,) buffer, via Pallas."""
    block_n = min(block_n, max(param.shape[0], 1))
    p, n = _pad1(param, block_n)
    g, _ = _pad1(grad, block_n)
    lr_vec = jnp.asarray([lr], dtype=param.dtype)
    grid = (p.shape[0] // block_n,)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(p.shape, param.dtype),
        interpret=True,
    )(p, g, lr_vec)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_n",))
def momentum_update(
    param, grad, velocity, lr, momentum=0.9, weight_decay=1e-4, block_n=DEFAULT_BLOCK_N
):
    """Heavy-ball update over flat (N,) buffers; returns (new_p, new_v).

    Hyperparameters ride in a length-3 vector so the kernel signature stays
    shape-stable across lr decay steps (the paper decays lr at epoch
    boundaries; we must not re-lower per decay).
    """
    block_n = min(block_n, max(param.shape[0], 1))
    p, n = _pad1(param, block_n)
    g, _ = _pad1(grad, block_n)
    v, _ = _pad1(velocity, block_n)
    h = jnp.asarray([lr, momentum, weight_decay], dtype=param.dtype)
    grid = (p.shape[0] // block_n,)
    new_p, new_v = pl.pallas_call(
        _momentum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, param.dtype),
            jax.ShapeDtypeStruct(p.shape, param.dtype),
        ],
        interpret=True,
    )(p, g, v, h)
    return new_p[:n], new_v[:n]
