"""Pallas kernel for the P-Reduce reduction (Layer 1).

The paper's Partial All-Reduce ends in a group-mean: every worker in group G
replaces its flattened parameter vector with the mean of the group's vectors
(the fused synchronization matrix F^G with entries 1/|G|). On the simulated
cluster the *schedule* of the reduction (ring reduce-scatter/all-gather) is
owned by the Rust collectives layer; the *arithmetic* hot-spot — reducing a
``(G, N)`` stack of replicas to the averaged vector — is this kernel.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the reduction is
bandwidth-bound VPU work. We tile N into ``block_n``-wide stripes; each grid
step holds a ``(G, block_n)`` tile in VMEM, reduces along axis 0, and writes
a ``(block_n,)`` stripe. VMEM footprint per step is
``(G + 1) * block_n * 4`` bytes — with the default ``block_n = 16384`` and
G = 8 that is ~0.6 MB, comfortably inside the ~16 MB VMEM budget while
giving the DMA engine long contiguous transfers.

interpret=True is mandatory on this CPU testbed (Mosaic custom-calls cannot
run on the CPU PJRT plugin); correctness is what we validate here, structure
is what we'd ship to a real TPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 16384


def _mean_kernel(stacked_ref, out_ref, *, group_size):
    """Reduce a (G, block_n) VMEM tile along axis 0 into a (block_n,) tile."""
    acc = jnp.zeros(out_ref.shape, dtype=jnp.float32)
    # G is small (2..16) and static: unrolled adds keep everything in VPU
    # registers instead of materializing an axis-0 reduce tree.
    for g in range(group_size):
        acc = acc + stacked_ref[g, :].astype(jnp.float32)
    out_ref[...] = (acc * (1.0 / group_size)).astype(out_ref.dtype)


def _weighted_kernel(stacked_ref, weights_ref, out_ref, *, group_size):
    """Weighted variant: out = sum_g w[g] * stacked[g, :]."""
    acc = jnp.zeros(out_ref.shape, dtype=jnp.float32)
    for g in range(group_size):
        acc = acc + weights_ref[g].astype(jnp.float32) * stacked_ref[g, :].astype(
            jnp.float32
        )
    out_ref[...] = acc.astype(out_ref.dtype)


def _pad_to_multiple(x, block_n):
    n = x.shape[-1]
    rem = n % block_n
    if rem == 0:
        return x, n
    pad = block_n - rem
    pad_widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, pad_widths), n


@functools.partial(jax.jit, static_argnames=("block_n",))
def preduce_mean(stacked, block_n=DEFAULT_BLOCK_N):
    """Group-mean of ``stacked`` with shape (G, N) -> (N,), via Pallas.

    N is padded up to a multiple of ``block_n`` so the grid is regular; the
    pad is sliced away afterwards (XLA fuses the pad/slice with the DMA).
    """
    group_size, n = stacked.shape
    block_n = min(block_n, max(n, 1))
    padded, orig_n = _pad_to_multiple(stacked, block_n)
    grid = (padded.shape[1] // block_n,)
    out = pl.pallas_call(
        functools.partial(_mean_kernel, group_size=group_size),
        grid=grid,
        in_specs=[pl.BlockSpec((group_size, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded.shape[1],), stacked.dtype),
        interpret=True,
    )(padded)
    return out[:orig_n]


@functools.partial(jax.jit, static_argnames=("block_n",))
def preduce_weighted(stacked, weights, block_n=DEFAULT_BLOCK_N):
    """Convex combination of replicas: (G, N), (G,) -> (N,), via Pallas.

    Used for the generalized doubly-stochastic row of F^G (e.g. when a
    group member is weighted down, as in bounded-staleness extensions).
    """
    group_size, n = stacked.shape
    block_n = min(block_n, max(n, 1))
    padded, orig_n = _pad_to_multiple(stacked, block_n)
    grid = (padded.shape[1] // block_n,)
    out = pl.pallas_call(
        functools.partial(_weighted_kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((group_size, block_n), lambda i: (0, i)),
            pl.BlockSpec((group_size,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded.shape[1],), stacked.dtype),
        interpret=True,
    )(padded, weights)
    return out[:orig_n]


def vmem_footprint_bytes(group_size, block_n=DEFAULT_BLOCK_N, dtype_bytes=4):
    """Estimated VMEM bytes held per grid step (input tile + output stripe).

    Reported in DESIGN.md §Perf; used by the block-size sweep in
    python/tests/test_perf_structure.py to keep blocks inside VMEM.
    """
    return (group_size + 1) * block_n * dtype_bytes
