"""Layer-2 JAX compute graphs: models, train steps, and P-Reduce graphs.

Everything here is *build-time only*: `aot.py` lowers these jitted functions
to HLO text once, and the Rust coordinator executes the artifacts via PJRT.
Python never runs on the training path.

Two model families (stand-ins for the paper's VGG-16/CIFAR-10 and
ResNet-50/ImageNet; see DESIGN.md §Hardware-Adaptation):

* :class:`MlpConfig` — an MLP classifier over dense features, the
  "medium model" used by most figure reproductions.
* :class:`TlmConfig` — a small decoder-only transformer LM over synthetic
  token streams, the "large model" for the end-to-end example.

All parameters live in a single flat ``(N,)`` float32 buffer — the paper's
§6.1 flatten-and-concatenate layout — so the Rust side treats a model as an
opaque vector and P-Reduce is a single group-mean over ``(G, N)``.
"""

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul as kmatmul
from .kernels import preduce as kpreduce
from .kernels import sgd as ksgd

# ---------------------------------------------------------------------------
# Flat-buffer parameter packing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """Shape of one logical tensor inside the flat buffer."""

    name: str
    shape: Tuple[int, ...]

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


def pack_specs(specs):
    """Offsets of each tensor inside the flat buffer; returns (offsets, total)."""
    offsets, off = {}, 0
    for s in specs:
        offsets[s.name] = (off, s.shape)
        off += s.size
    return offsets, off


def unpack(flat, offsets, name):
    off, shape = offsets[name]
    size = 1
    for d in shape:
        size *= d
    return jax.lax.dynamic_slice(flat, (off,), (size,)).reshape(shape)


# ---------------------------------------------------------------------------
# MLP classifier
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    """MLP over dense features. Default is the figure-reproduction size."""

    in_dim: int = 32
    hidden: Tuple[int, ...] = (128, 128)
    classes: int = 10
    batch: int = 128
    use_pallas: bool = False

    def specs(self):
        dims = (self.in_dim,) + self.hidden + (self.classes,)
        out = []
        for i in range(len(dims) - 1):
            out.append(TensorSpec(f"w{i}", (dims[i], dims[i + 1])))
            out.append(TensorSpec(f"b{i}", (dims[i + 1],)))
        return out

    @property
    def layers(self) -> int:
        return len(self.hidden) + 1

    def param_count(self) -> int:
        return pack_specs(self.specs())[1]


def mlp_init(cfg: MlpConfig, seed: int = 0) -> jnp.ndarray:
    """He-initialized flat parameter buffer."""
    offsets, total = pack_specs(cfg.specs())
    key = jax.random.PRNGKey(seed)
    flat = jnp.zeros((total,), jnp.float32)
    for spec in cfg.specs():
        off, shape = offsets[spec.name]
        if spec.name.startswith("w"):
            key, sub = jax.random.split(key)
            fan_in = shape[0]
            w = jax.random.normal(sub, shape) * jnp.sqrt(2.0 / fan_in)
            flat = jax.lax.dynamic_update_slice(flat, w.reshape(-1), (off,))
    return flat


def _mlp_logits(cfg: MlpConfig, flat, x):
    offsets, _ = pack_specs(cfg.specs())
    mm = (lambda a, b: kmatmul.matmul(a, b)) if cfg.use_pallas else jnp.matmul
    h = x
    for i in range(cfg.layers):
        w = unpack(flat, offsets, f"w{i}")
        b = unpack(flat, offsets, f"b{i}")
        h = mm(h, w) + b
        if i < cfg.layers - 1:
            h = jax.nn.relu(h)
    return h


def mlp_loss(cfg: MlpConfig, flat, x, y):
    """Mean softmax cross-entropy over the batch."""
    logits = _mlp_logits(cfg, flat, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def mlp_train_step(cfg: MlpConfig):
    """Returns f(flat, x, y, lr) -> (new_flat, loss): one SGD iteration."""

    def step(flat, x, y, lr):
        loss, grad = jax.value_and_grad(lambda p: mlp_loss(cfg, p, x, y))(flat)
        if cfg.use_pallas:
            new_flat = ksgd.sgd_update(flat, grad, lr)
        else:
            new_flat = flat - lr * grad
        return new_flat, loss

    return step


# ---------------------------------------------------------------------------
# Tiny decoder-only transformer LM
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TlmConfig:
    """Decoder-only transformer LM over synthetic tokens.

    The default (~2.8M params) keeps CPU-PJRT train steps fast enough for a
    few hundred e2e steps; `large()` is a ~110M-param config matching the
    system-prompt scale reference, lowered on demand (same graph, bigger
    shapes).
    """

    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512
    seq: int = 64
    batch: int = 8
    use_pallas: bool = False

    @classmethod
    def large(cls):
        return cls(
            vocab=32000, d_model=768, n_heads=12, n_layers=12, d_ff=3072, seq=256,
            batch=8,
        )

    def specs(self):
        s = [
            TensorSpec("tok_emb", (self.vocab, self.d_model)),
            TensorSpec("pos_emb", (self.seq, self.d_model)),
        ]
        for i in range(self.n_layers):
            s += [
                TensorSpec(f"l{i}.ln1_g", (self.d_model,)),
                TensorSpec(f"l{i}.wqkv", (self.d_model, 3 * self.d_model)),
                TensorSpec(f"l{i}.wo", (self.d_model, self.d_model)),
                TensorSpec(f"l{i}.ln2_g", (self.d_model,)),
                TensorSpec(f"l{i}.w1", (self.d_model, self.d_ff)),
                TensorSpec(f"l{i}.w2", (self.d_ff, self.d_model)),
            ]
        s.append(TensorSpec("lnf_g", (self.d_model,)))
        return s

    def param_count(self) -> int:
        return pack_specs(self.specs())[1]


def tlm_init(cfg: TlmConfig, seed: int = 0) -> jnp.ndarray:
    offsets, total = pack_specs(cfg.specs())
    key = jax.random.PRNGKey(seed)
    flat = jnp.zeros((total,), jnp.float32)
    for spec in cfg.specs():
        off, shape = offsets[spec.name]
        key, sub = jax.random.split(key)
        if spec.name.endswith(("_g",)):
            t = jnp.ones(shape)
        else:
            scale = 0.02
            t = jax.random.normal(sub, shape) * scale
        flat = jax.lax.dynamic_update_slice(flat, t.reshape(-1), (off,))
    return flat


def _rmsnorm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _tlm_logits(cfg: TlmConfig, flat, tokens):
    offsets, _ = pack_specs(cfg.specs())
    get = lambda n: unpack(flat, offsets, n)  # noqa: E731
    mm = (
        (lambda a, b: kmatmul.matmul(a, b)) if cfg.use_pallas else jnp.matmul
    )
    B, T = tokens.shape
    h = get("tok_emb")[tokens] + get("pos_emb")[None, :T, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    dh = cfg.d_model // cfg.n_heads
    for i in range(cfg.n_layers):
        x = _rmsnorm(h, get(f"l{i}.ln1_g"))
        qkv = mm(x.reshape(B * T, -1), get(f"l{i}.wqkv")).reshape(B, T, 3, cfg.n_heads, dh)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        att = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(dh)
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhts,bshd->bthd", att, v).reshape(B, T, cfg.d_model)
        h = h + mm(o.reshape(B * T, -1), get(f"l{i}.wo")).reshape(B, T, -1)
        x = _rmsnorm(h, get(f"l{i}.ln2_g"))
        f = jax.nn.gelu(mm(x.reshape(B * T, -1), get(f"l{i}.w1")))
        h = h + mm(f, get(f"l{i}.w2")).reshape(B, T, -1)
    h = _rmsnorm(h, get("lnf_g"))
    return mm(h.reshape(B * T, -1), get("tok_emb").T).reshape(B, T, cfg.vocab)


def tlm_loss(cfg: TlmConfig, flat, tokens):
    """Next-token cross-entropy; targets are tokens shifted by one."""
    logits = _tlm_logits(cfg, flat, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def tlm_train_step(cfg: TlmConfig):
    """Returns f(flat, tokens, lr) -> (new_flat, loss): one SGD iteration."""

    def step(flat, tokens, lr):
        loss, grad = jax.value_and_grad(lambda p: tlm_loss(cfg, p, tokens))(flat)
        if cfg.use_pallas:
            new_flat = ksgd.sgd_update(flat, grad, lr)
        else:
            new_flat = flat - lr * grad
        return new_flat, loss

    return step


# ---------------------------------------------------------------------------
# P-Reduce graphs (group averaging as standalone artifacts)
# ---------------------------------------------------------------------------


def preduce_graph(group_size: int, n: int, use_pallas: bool = True):
    """Returns f(stacked (G, N)) -> (N,): the F^G group-mean.

    This is the computation the Rust coordinator executes when a group
    completes its P-Reduce rendezvous; the ring *schedule* is Rust's, the
    arithmetic is this artifact's.
    """

    def graph(stacked):
        if use_pallas:
            return kpreduce.preduce_mean(stacked)
        return jnp.mean(stacked, axis=0)

    return graph


def preduce_weighted_graph(group_size: int, n: int, use_pallas: bool = True):
    """Returns f(stacked (G, N), weights (G,)) -> (N,): weighted F^G row."""

    def graph(stacked, weights):
        if use_pallas:
            return kpreduce.preduce_weighted(stacked, weights)
        return jnp.tensordot(weights, stacked, axes=1)

    return graph
