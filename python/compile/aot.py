"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that this image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``). The text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Each artifact ``<name>.hlo.txt`` gets a ``<name>.meta.json`` sidecar
describing input shapes/dtypes and model metadata, which the Rust loader
(`runtime::artifact`) parses with its own mini-JSON reader.

Run once via ``make artifacts``; a content hash makes it a no-op when the
compile/ sources are unchanged.

Usage:  python -m compile.aot --out-dir ../artifacts [--family all|mlp|tlm|preduce]
        [--report]   # also print per-artifact HLO op histograms (L2 perf check)
"""

import argparse
import collections
import hashlib
import json
import os
import re
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dt(s):
    return str(s.dtype.name if hasattr(s.dtype, "name") else s.dtype)


def lower_artifact(name, fn, arg_specs, meta, out_dir):
    """Lower ``fn`` at ``arg_specs`` and write <name>.hlo.txt + sidecar."""
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    sidecar = dict(meta)
    sidecar["name"] = name
    sidecar["inputs"] = [
        {"shape": list(s.shape), "dtype": _dt(s)} for s in arg_specs
    ]
    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(sidecar, f, indent=1, sort_keys=True)
    print(f"  wrote {name}: {len(text)} chars, inputs={sidecar['inputs']}")
    return text


def hlo_op_histogram(text):
    """Crude per-opcode counts from HLO text — the L2 fusion/perf report."""
    hist = collections.Counter()
    for m in re.finditer(r"=\s+\S+\s+([a-z][a-z0-9-]*)\(", text):
        hist[m.group(1)] += 1
    return hist


# ---------------------------------------------------------------------------
# Artifact families
# ---------------------------------------------------------------------------


def build_mlp(out_dir, report):
    """MLP train/eval steps: the figure-reproduction model."""
    texts = {}
    for tag, use_pallas in (("", False), ("_pallas", True)):
        cfg = M.MlpConfig(use_pallas=use_pallas)
        step = M.mlp_train_step(cfg)
        n = cfg.param_count()
        meta = {
            "kind": "mlp_train_step",
            "param_count": n,
            "batch": cfg.batch,
            "in_dim": cfg.in_dim,
            "classes": cfg.classes,
            "use_pallas": use_pallas,
            "outputs": ["new_flat", "loss"],
        }
        texts[tag] = lower_artifact(
            f"mlp_train_step{tag}",
            step,
            [
                spec((n,)),
                spec((cfg.batch, cfg.in_dim)),
                spec((cfg.batch,), I32),
                spec((), F32),
            ],
            meta,
            out_dir,
        )
    cfg = M.MlpConfig()
    n = cfg.param_count()
    lower_artifact(
        "mlp_eval",
        lambda flat, x, y: (M.mlp_loss(cfg, flat, x, y),),
        [spec((n,)), spec((cfg.batch, cfg.in_dim)), spec((cfg.batch,), I32)],
        {"kind": "mlp_eval", "param_count": n, "outputs": ["loss"]},
        out_dir,
    )
    lower_artifact(
        "mlp_init",
        lambda seed: (M.mlp_init(cfg, 0) if False else _mlp_init_traced(cfg, seed),),
        [spec((), I32)],
        {"kind": "mlp_init", "param_count": n, "outputs": ["flat"]},
        out_dir,
    )
    if report:
        for tag, text in texts.items():
            hist = hlo_op_histogram(text)
            fusions = hist.get("fusion", 0)
            print(f"  [report] mlp{tag}: top ops {hist.most_common(6)} fusions={fusions}")


def _mlp_init_traced(cfg, seed):
    """Traced-seed variant of mlp_init so initialization is an artifact too."""
    offsets, total = M.pack_specs(cfg.specs())
    key = jax.random.PRNGKey(seed)
    flat = jnp.zeros((total,), jnp.float32)
    for s in cfg.specs():
        off, shape = offsets[s.name]
        if s.name.startswith("w"):
            key, sub = jax.random.split(key)
            w = jax.random.normal(sub, shape) * jnp.sqrt(2.0 / shape[0])
            flat = jax.lax.dynamic_update_slice(flat, w.reshape(-1), (off,))
    return flat


def build_tlm(out_dir, report, large=False):
    """Transformer-LM train/eval steps: the end-to-end example model."""
    cfg = M.TlmConfig.large() if large else M.TlmConfig()
    step = M.tlm_train_step(cfg)
    n = cfg.param_count()
    suffix = "_large" if large else ""
    meta = {
        "kind": "tlm_train_step",
        "param_count": n,
        "batch": cfg.batch,
        "seq": cfg.seq,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "outputs": ["new_flat", "loss"],
    }
    text = lower_artifact(
        f"tlm_train_step{suffix}",
        step,
        [spec((n,)), spec((cfg.batch, cfg.seq), I32), spec((), F32)],
        meta,
        out_dir,
    )
    lower_artifact(
        f"tlm_init{suffix}",
        lambda seed: (_tlm_init_traced(cfg, seed),),
        [spec((), I32)],
        {"kind": "tlm_init", "param_count": n, "outputs": ["flat"]},
        out_dir,
    )
    if report:
        hist = hlo_op_histogram(text)
        print(f"  [report] tlm{suffix}: params={n} top ops {hist.most_common(6)}")


def _tlm_init_traced(cfg, seed):
    offsets, total = M.pack_specs(cfg.specs())
    key = jax.random.PRNGKey(seed)
    flat = jnp.zeros((total,), jnp.float32)
    for s in cfg.specs():
        off, shape = offsets[s.name]
        key, sub = jax.random.split(key)
        if s.name.endswith("_g"):
            t = jnp.ones(shape)
        else:
            t = jax.random.normal(sub, shape) * 0.02
        flat = jax.lax.dynamic_update_slice(flat, t.reshape(-1), (off,))
    return flat


def build_preduce(out_dir, report):
    """Group-mean artifacts for each model's flat size and group sizes 2..8.

    The Pallas path is used for the MLP sizes (fast enough under interpret);
    the TLM sizes use the jnp path of the *same* graph so the e2e example is
    not bottlenecked by interpret-mode emulation. Numerics are identical
    (pytest asserts kernel == ref).
    """
    mlp_n = M.MlpConfig().param_count()
    tlm_n = M.TlmConfig().param_count()
    for model, n, use_pallas in (("mlp", mlp_n, True), ("tlm", tlm_n, False)):
        for g in (2, 3, 4, 8):
            fn = M.preduce_graph(g, n, use_pallas=use_pallas)
            lower_artifact(
                f"preduce_{model}_g{g}",
                lambda stacked, fn=fn: (fn(stacked),),
                [spec((g, n))],
                {
                    "kind": "preduce",
                    "model": model,
                    "group_size": g,
                    "param_count": n,
                    "use_pallas": use_pallas,
                    "outputs": ["mean"],
                },
                out_dir,
            )
    # One weighted variant (used by the slowdown-weighting extension).
    fnw = M.preduce_weighted_graph(4, mlp_n, use_pallas=True)
    lower_artifact(
        "preduce_mlp_g4_weighted",
        lambda stacked, w: (fnw(stacked, w),),
        [spec((4, mlp_n)), spec((4,))],
        {
            "kind": "preduce_weighted",
            "model": "mlp",
            "group_size": 4,
            "param_count": mlp_n,
            "outputs": ["avg"],
        },
        out_dir,
    )


FAMILIES = {"mlp": build_mlp, "tlm": build_tlm, "preduce": build_preduce}


def source_fingerprint():
    """Hash of compile/ sources; lets `make artifacts` skip when unchanged."""
    h = hashlib.sha256()
    base = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in sorted(os.walk(base)):
        for fname in sorted(files):
            if fname.endswith(".py"):
                with open(os.path.join(root, fname), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--family", default="all", choices=["all"] + list(FAMILIES))
    p.add_argument("--large", action="store_true", help="also lower the ~110M TLM")
    p.add_argument("--report", action="store_true", help="print HLO op histograms")
    p.add_argument("--force", action="store_true")
    args = p.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    stamp = os.path.join(args.out_dir, ".fingerprint")
    fp = source_fingerprint() + (":large" if args.large else "")
    if not args.force and os.path.exists(stamp):
        with open(stamp) as f:
            if f.read().strip() == fp and args.family == "all":
                print("artifacts up to date (fingerprint match)")
                return 0

    fams = list(FAMILIES) if args.family == "all" else [args.family]
    for fam in fams:
        print(f"[aot] lowering family: {fam}")
        if fam == "tlm":
            build_tlm(args.out_dir, args.report)
            if args.large:
                build_tlm(args.out_dir, args.report, large=True)
        else:
            FAMILIES[fam](args.out_dir, args.report)
    if args.family == "all":
        with open(stamp, "w") as f:
            f.write(fp)
    print("[aot] done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
