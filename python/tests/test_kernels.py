"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/block sizes; assert_allclose against ref.py.
This is the CORE correctness signal for everything the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Optional dependency: without hypothesis the rest of the python suite
# must still run green — skip this module instead of erroring at import.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as kmm
from compile.kernels import preduce as kpr
from compile.kernels import ref
from compile.kernels import sgd as ksgd

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


# ---------------------------------------------------------------------------
# preduce
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    g=st.integers(2, 8),
    n=st.integers(1, 3000),
    block=st.sampled_from([64, 256, 1024]),
    seed=st.integers(0, 2**16),
)
def test_preduce_mean_matches_ref(g, n, block, seed):
    stacked = rand(seed, (g, n))
    got = kpr.preduce_mean(stacked, block_n=block)
    want = ref.preduce_mean(stacked)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    g=st.integers(2, 6),
    n=st.integers(1, 2000),
    block=st.sampled_from([128, 512]),
    seed=st.integers(0, 2**16),
)
def test_preduce_weighted_matches_ref(g, n, block, seed):
    stacked = rand(seed, (g, n))
    w = jax.nn.softmax(rand(seed + 1, (g,)))  # doubly-stochastic row
    got = kpr.preduce_weighted(stacked, w, block_n=block)
    want = ref.preduce_weighted(stacked, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_preduce_mean_uniform_weights_equiv():
    """F^G row with uniform 1/|G| weights == plain group mean."""
    stacked = rand(7, (4, 513))
    w = jnp.full((4,), 0.25)
    np.testing.assert_allclose(
        kpr.preduce_weighted(stacked, w, block_n=128),
        kpr.preduce_mean(stacked, block_n=128),
        rtol=1e-5,
        atol=1e-6,
    )


def test_preduce_idempotent():
    """Averaging already-identical replicas is the identity: F^G F^G = F^G."""
    x = rand(3, (1, 777))
    stacked = jnp.tile(x, (5, 1))
    got = kpr.preduce_mean(stacked, block_n=256)
    np.testing.assert_allclose(got, x[0], rtol=1e-6, atol=1e-7)


def test_preduce_exact_block_multiple():
    """No-padding path: N an exact multiple of block_n."""
    stacked = rand(11, (3, 1024))
    np.testing.assert_allclose(
        kpr.preduce_mean(stacked, block_n=256),
        ref.preduce_mean(stacked),
        rtol=1e-5,
    )


def test_preduce_single_element():
    stacked = rand(5, (2, 1))
    np.testing.assert_allclose(
        kpr.preduce_mean(stacked, block_n=64), ref.preduce_mean(stacked), rtol=1e-6
    )


def test_preduce_block_larger_than_n():
    stacked = rand(9, (4, 37))
    np.testing.assert_allclose(
        kpr.preduce_mean(stacked, block_n=4096),
        ref.preduce_mean(stacked),
        rtol=1e-5,
    )


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    a = rand(seed, (m, k))
    b = rand(seed + 1, (k, n))
    got = kmm._matmul_impl(a, b, bm=32, bn=32, bk=32)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matmul_block_boundary_shapes():
    """Exact multiples, one-over, one-under the block size."""
    for m, k, n in [(32, 32, 32), (33, 31, 32), (64, 96, 33), (1, 128, 1)]:
        a, b = rand(m + k, (m, k)), rand(n, (k, n))
        np.testing.assert_allclose(
            kmm._matmul_impl(a, b, bm=32, bn=32, bk=32),
            ref.matmul(a, b),
            rtol=2e-4,
            atol=2e-4,
        )


def test_matmul_custom_vjp_matches_jnp_grads():
    """The hand-written VJP must agree with jnp.matmul autodiff."""
    a, b = rand(1, (24, 40)), rand(2, (40, 16))

    def loss_pallas(a, b):
        return jnp.sum(jnp.sin(kmm.matmul(a, b)))

    def loss_ref(a, b):
        return jnp.sum(jnp.sin(jnp.matmul(a, b)))

    ga_p, gb_p = jax.grad(loss_pallas, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(loss_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_p, ga_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gb_p, gb_r, rtol=1e-4, atol=1e-4)


def test_matmul_mxu_utilization_estimate():
    assert kmm.mxu_utilization_estimate(128, 128, 128) == 1.0
    assert kmm.mxu_utilization_estimate(129, 128, 128) < 1.0
    u = kmm.mxu_utilization_estimate(100, 100, 100)
    assert 0.0 < u < 1.0


# ---------------------------------------------------------------------------
# sgd / momentum
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 4000),
    lr=st.floats(1e-4, 1.0),
    block=st.sampled_from([128, 1024]),
    seed=st.integers(0, 2**16),
)
def test_sgd_update_matches_ref(n, lr, block, seed):
    p, g = rand(seed, (n,)), rand(seed + 1, (n,))
    got = ksgd.sgd_update(p, g, lr, block_n=block)
    want = ref.sgd_update(p, g, lr)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 3000),
    seed=st.integers(0, 2**16),
)
def test_momentum_update_matches_ref(n, seed):
    p, g, v = rand(seed, (n,)), rand(seed + 1, (n,)), rand(seed + 2, (n,))
    lr, mom, wd = 0.128, 0.9, 1e-4  # the paper's ResNet-50 hyperparameters
    got_p, got_v = ksgd.momentum_update(p, g, v, lr, mom, wd, block_n=512)
    want_p, want_v = ref.momentum_update(p, g, v, lr, mom, wd)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-6)


def test_momentum_zero_velocity_is_sgd_plus_wd():
    p, g = rand(1, (100,)), rand(2, (100,))
    v = jnp.zeros_like(p)
    new_p, _ = ksgd.momentum_update(p, g, v, 0.1, 0.9, 0.0, block_n=64)
    np.testing.assert_allclose(new_p, p - 0.1 * g, rtol=1e-5, atol=1e-6)


def test_vmem_footprints_within_budget():
    """Default blocks must fit TPU VMEM (~16 MiB) with margin."""
    vmem = 16 * 1024 * 1024
    assert kpr.vmem_footprint_bytes(group_size=8) < vmem // 4
    assert kmm.vmem_footprint_bytes() < vmem // 4
