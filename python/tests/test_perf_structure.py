"""Perf-structure checks for the Layer-1 kernels (DESIGN.md §Perf).

The kernels run interpreted on this CPU testbed, so wall-clock is not the
signal — *structure* is: block sizes must keep every grid step's working
set inside the TPU VMEM budget, and the tiling must not silently waste
MXU issue slots. This is the block-size sweep referenced by
``preduce.vmem_footprint_bytes`` and EXPERIMENTS.md §MEAN_BLOCK-sweep.

Pure arithmetic on the footprint/utilization helpers — no Pallas
execution — so it runs anywhere the package imports.
"""

from compile.kernels import matmul as kmm
from compile.kernels import preduce as kpr

# ~16 MB of VMEM per TensorCore; leave headroom for double-buffering
# (BlockSpec pipelines the next tile's DMA behind the current compute).
VMEM_BUDGET = 16 * 1024 * 1024
HEADROOM = 0.5


def test_preduce_block_sweep_stays_inside_vmem():
    for group_size in (2, 3, 4, 8, 16):
        footprint = kpr.vmem_footprint_bytes(group_size)
        assert footprint <= VMEM_BUDGET * HEADROOM, (
            f"G={group_size}: {footprint} bytes exceeds the double-buffered "
            f"VMEM budget"
        )
    # the documented default-shape number: (8 + 1) * 16384 * 4 ≈ 0.6 MB
    assert kpr.vmem_footprint_bytes(8) == 9 * kpr.DEFAULT_BLOCK_N * 4


def test_preduce_footprint_scales_linearly_in_group_size():
    base = kpr.vmem_footprint_bytes(2)
    for g in (3, 4, 8):
        expect = (g + 1) / 3 * base
        assert abs(kpr.vmem_footprint_bytes(g) - expect) < 1e-6


def test_matmul_tiles_stay_inside_vmem():
    footprint = kmm.vmem_footprint_bytes()
    assert footprint <= VMEM_BUDGET * HEADROOM
    # three 128x128 f32 tiles
    assert footprint == 3 * 128 * 128 * 4


def test_mxu_utilization_estimate_behaves():
    # aligned shapes: no pad waste
    assert kmm.mxu_utilization_estimate(256, 256, 256) == 1.0
    # off-by-one shapes pay padding; utilization strictly between 0 and 1
    u = kmm.mxu_utilization_estimate(129, 129, 129)
    assert 0.0 < u < 1.0
    # growing an aligned dim cannot reduce utilization
    assert kmm.mxu_utilization_estimate(256, 256, 384) == 1.0
