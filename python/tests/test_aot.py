"""AOT pipeline: lowering produces loadable HLO text + correct sidecars."""

import json
import os

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def art_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    aot.build_mlp(str(d), report=False)
    return str(d)


def test_hlo_text_is_parseable_hlo(art_dir):
    text = open(os.path.join(art_dir, "mlp_train_step.hlo.txt")).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: root of entry must be a tuple
    assert "tuple(" in text or "(f32[" in text


def test_sidecar_shapes_match_config(art_dir):
    meta = json.load(open(os.path.join(art_dir, "mlp_train_step.meta.json")))
    cfg = M.MlpConfig()
    n = cfg.param_count()
    assert meta["param_count"] == n
    assert meta["inputs"][0] == {"shape": [n], "dtype": "float32"}
    assert meta["inputs"][1] == {"shape": [cfg.batch, cfg.in_dim], "dtype": "float32"}
    assert meta["inputs"][2] == {"shape": [cfg.batch], "dtype": "int32"}
    assert meta["outputs"] == ["new_flat", "loss"]


def test_pallas_variant_same_signature(art_dir):
    a = json.load(open(os.path.join(art_dir, "mlp_train_step.meta.json")))
    b = json.load(open(os.path.join(art_dir, "mlp_train_step_pallas.meta.json")))
    assert a["inputs"] == b["inputs"]
    assert b["use_pallas"] is True


def test_op_histogram_counts_something(art_dir):
    text = open(os.path.join(art_dir, "mlp_train_step.hlo.txt")).read()
    hist = aot.hlo_op_histogram(text)
    assert sum(hist.values()) > 10
    assert "dot" in hist or "fusion" in hist


def test_fingerprint_stable():
    assert aot.source_fingerprint() == aot.source_fingerprint()


def test_fingerprint_skip(tmp_path, capsys):
    """Second `all` run with matching fingerprint must be a no-op."""
    stamp = tmp_path / ".fingerprint"
    stamp.write_text(aot.source_fingerprint())
    rc = aot.main(["--out-dir", str(tmp_path), "--family", "all"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "up to date" in out


def test_preduce_artifact_roundtrip(tmp_path):
    """preduce graphs lower and sidecars carry group size + param count."""
    import jax
    import jax.numpy as jnp

    fn = M.preduce_graph(3, 128, use_pallas=False)
    aot.lower_artifact(
        "preduce_test_g3",
        lambda s: (fn(s),),
        [aot.spec((3, 128))],
        {"kind": "preduce", "group_size": 3, "param_count": 128},
        str(tmp_path),
    )
    meta = json.load(open(tmp_path / "preduce_test_g3.meta.json"))
    assert meta["group_size"] == 3
    text = open(tmp_path / "preduce_test_g3.hlo.txt").read()
    assert text.startswith("HloModule")
