"""Layer-2 correctness: models, flat-buffer packing, train-step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def small_mlp(use_pallas=False):
    return M.MlpConfig(in_dim=8, hidden=(16,), classes=4, batch=16, use_pallas=use_pallas)


def small_tlm(use_pallas=False):
    return M.TlmConfig(
        vocab=32, d_model=16, n_heads=2, n_layers=1, d_ff=32, seq=16, batch=4,
        use_pallas=use_pallas,
    )


def synth_batch(cfg, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (cfg.batch, cfg.in_dim))
    y = jax.random.randint(ky, (cfg.batch,), 0, cfg.classes)
    return x, y


# ---------------------------------------------------------------------------
# flat-buffer packing
# ---------------------------------------------------------------------------


def test_pack_specs_offsets_contiguous():
    specs = [M.TensorSpec("a", (3, 4)), M.TensorSpec("b", (5,)), M.TensorSpec("c", (2, 2, 2))]
    offsets, total = M.pack_specs(specs)
    assert offsets["a"] == (0, (3, 4))
    assert offsets["b"] == (12, (5,))
    assert offsets["c"] == (17, (2, 2, 2))
    assert total == 25


def test_unpack_roundtrip():
    specs = [M.TensorSpec("a", (3, 4)), M.TensorSpec("b", (5,))]
    offsets, total = M.pack_specs(specs)
    flat = jnp.arange(total, dtype=jnp.float32)
    a = M.unpack(flat, offsets, "a")
    b = M.unpack(flat, offsets, "b")
    np.testing.assert_array_equal(a, jnp.arange(12.0).reshape(3, 4))
    np.testing.assert_array_equal(b, jnp.arange(12.0, 17.0))


def test_mlp_param_count_formula():
    cfg = small_mlp()
    expect = 8 * 16 + 16 + 16 * 4 + 4
    assert cfg.param_count() == expect


def test_tlm_param_count_positive_and_large_config_scale():
    assert small_tlm().param_count() > 0
    # large() should be on the order of 100M params (scale reference)
    assert 5e7 < M.TlmConfig.large().param_count() < 3e8


# ---------------------------------------------------------------------------
# MLP semantics
# ---------------------------------------------------------------------------


def test_mlp_init_deterministic():
    cfg = small_mlp()
    np.testing.assert_array_equal(M.mlp_init(cfg, 3), M.mlp_init(cfg, 3))
    assert not np.allclose(M.mlp_init(cfg, 3), M.mlp_init(cfg, 4))


def test_mlp_loss_finite_and_near_uniform_at_init():
    cfg = small_mlp()
    flat = M.mlp_init(cfg, 0)
    x, y = synth_batch(cfg)
    loss = M.mlp_loss(cfg, flat, x, y)
    assert np.isfinite(loss)
    # At init, loss should be near ln(classes)
    assert abs(float(loss) - np.log(cfg.classes)) < 1.5


def test_mlp_train_step_reduces_loss():
    cfg = small_mlp()
    step = jax.jit(M.mlp_train_step(cfg))
    flat = M.mlp_init(cfg, 0)
    x, y = synth_batch(cfg)
    losses = []
    for _ in range(30):
        flat, loss = step(flat, x, y, jnp.float32(0.1))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7


def test_mlp_pallas_and_jnp_paths_agree():
    """use_pallas must change the implementation, not the math."""
    cfg_j, cfg_p = small_mlp(False), small_mlp(True)
    flat = M.mlp_init(cfg_j, 0)
    x, y = synth_batch(cfg_j)
    step_j = M.mlp_train_step(cfg_j)
    step_p = M.mlp_train_step(cfg_p)
    fj, lj = step_j(flat, x, y, jnp.float32(0.05))
    fp, lp = step_p(flat, x, y, jnp.float32(0.05))
    np.testing.assert_allclose(lj, lp, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(fj, fp, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# TLM semantics
# ---------------------------------------------------------------------------


def test_tlm_loss_near_uniform_at_init():
    cfg = small_tlm()
    flat = M.tlm_init(cfg, 0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (cfg.batch, cfg.seq), 0, cfg.vocab)
    loss = M.tlm_loss(cfg, flat, toks)
    assert np.isfinite(loss)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


def test_tlm_train_step_reduces_loss_on_fixed_batch():
    cfg = small_tlm()
    step = jax.jit(M.tlm_train_step(cfg))
    flat = M.tlm_init(cfg, 0)
    toks = jax.random.randint(jax.random.PRNGKey(2), (cfg.batch, cfg.seq), 0, cfg.vocab)
    first = None
    for i in range(25):
        flat, loss = step(flat, toks, jnp.float32(0.05))
        if first is None:
            first = float(loss)
    assert float(loss) < first


def test_tlm_causality():
    """Changing a future token must not affect earlier logits."""
    cfg = small_tlm()
    flat = M.tlm_init(cfg, 0)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, cfg.seq), 0, cfg.vocab)
    logits1 = M._tlm_logits(cfg, flat, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab)
    logits2 = M._tlm_logits(cfg, flat, toks2)
    np.testing.assert_allclose(logits1[0, :-1], logits2[0, :-1], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# P-Reduce graphs = the convergence-critical averaging semantics
# ---------------------------------------------------------------------------


def test_preduce_graph_matches_mean():
    g = M.preduce_graph(3, 100, use_pallas=False)
    stacked = jax.random.normal(jax.random.PRNGKey(0), (3, 100))
    np.testing.assert_allclose(g(stacked), jnp.mean(stacked, axis=0), rtol=1e-6)


def test_preduce_graph_pallas_jnp_agree():
    gp = M.preduce_graph(4, 300, use_pallas=True)
    gj = M.preduce_graph(4, 300, use_pallas=False)
    stacked = jax.random.normal(jax.random.PRNGKey(1), (4, 300))
    np.testing.assert_allclose(gp(stacked), gj(stacked), rtol=1e-5, atol=1e-6)


def test_preduce_preserves_mean_of_ensemble():
    """Doubly-stochastic property: total ensemble mass is conserved."""
    g = M.preduce_graph(4, 50, use_pallas=False)
    stacked = jax.random.normal(jax.random.PRNGKey(2), (4, 50))
    avg = g(stacked)
    after = jnp.tile(avg[None], (4, 1))
    np.testing.assert_allclose(
        jnp.mean(after, axis=0), jnp.mean(stacked, axis=0), rtol=1e-6
    )


def test_decentralized_averaging_contracts_disagreement():
    """One P-Reduce strictly shrinks replica variance (spectral-gap intuition)."""
    stacked = jax.random.normal(jax.random.PRNGKey(4), (4, 64))
    g = M.preduce_graph(2, 64, use_pallas=False)
    # average replicas {0,1} and {2,3}
    a = g(stacked[:2])
    b = g(stacked[2:])
    after = jnp.stack([a, a, b, b])
    var_before = float(jnp.var(stacked, axis=0).mean())
    var_after = float(jnp.var(after, axis=0).mean())
    assert var_after < var_before
